//! `Array4` — the ParArrayND analog: a rank-4 row-major array of `Real`
//! with shape [V, Z, Y, X]. Scalars/vectors/tensors are flattened into the
//! leading component axis exactly like ParArrayND flattens higher ranks.

use crate::Real;

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Array4 {
    dims: [usize; 4],
    data: Vec<Real>,
}

impl Array4 {
    pub fn zeros(dims: [usize; 4]) -> Self {
        Array4 { dims, data: vec![0.0; dims.iter().product()] }
    }

    pub fn empty() -> Self {
        Array4 { dims: [0; 4], data: Vec::new() }
    }

    #[inline]
    pub fn dims(&self) -> [usize; 4] {
        self.dims
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn idx(&self, v: usize, k: usize, j: usize, i: usize) -> usize {
        debug_assert!(v < self.dims[0] && k < self.dims[1] && j < self.dims[2] && i < self.dims[3]);
        ((v * self.dims[1] + k) * self.dims[2] + j) * self.dims[3] + i
    }

    #[inline]
    pub fn get(&self, v: usize, k: usize, j: usize, i: usize) -> Real {
        self.data[self.idx(v, k, j, i)]
    }

    #[inline]
    pub fn set(&mut self, v: usize, k: usize, j: usize, i: usize, val: Real) {
        let ix = self.idx(v, k, j, i);
        self.data[ix] = val;
    }

    #[inline]
    pub fn at_mut(&mut self, v: usize, k: usize, j: usize, i: usize) -> &mut Real {
        let ix = self.idx(v, k, j, i);
        &mut self.data[ix]
    }

    pub fn as_slice(&self) -> &[Real] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [Real] {
        &mut self.data
    }

    /// Contiguous slice of one component plane [Z, Y, X].
    pub fn comp(&self, v: usize) -> &[Real] {
        let n = self.dims[1] * self.dims[2] * self.dims[3];
        &self.data[v * n..(v + 1) * n]
    }

    pub fn comp_mut(&mut self, v: usize) -> &mut [Real] {
        let n = self.dims[1] * self.dims[2] * self.dims[3];
        &mut self.data[v * n..(v + 1) * n]
    }

    pub fn fill(&mut self, val: Real) {
        self.data.fill(val);
    }

    /// Deep copy of another array (dims must match).
    pub fn copy_from(&mut self, other: &Array4) {
        debug_assert_eq!(self.dims, other.dims);
        self.data.copy_from_slice(&other.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_layout_is_row_major_x_fastest() {
        let mut a = Array4::zeros([2, 3, 4, 5]);
        a.set(0, 0, 0, 1, 1.0);
        a.set(0, 0, 1, 0, 2.0);
        a.set(0, 1, 0, 0, 3.0);
        a.set(1, 0, 0, 0, 4.0);
        assert_eq!(a.as_slice()[1], 1.0);
        assert_eq!(a.as_slice()[5], 2.0);
        assert_eq!(a.as_slice()[20], 3.0);
        assert_eq!(a.as_slice()[60], 4.0);
    }

    #[test]
    fn comp_slices() {
        let mut a = Array4::zeros([2, 1, 2, 2]);
        a.comp_mut(1).fill(7.0);
        assert!(a.comp(0).iter().all(|&x| x == 0.0));
        assert!(a.comp(1).iter().all(|&x| x == 7.0));
    }

    #[test]
    fn copy_from() {
        let mut a = Array4::zeros([1, 1, 2, 2]);
        let mut b = Array4::zeros([1, 1, 2, 2]);
        b.fill(3.0);
        a.copy_from(&b);
        assert_eq!(a, b);
    }
}
