//! Native (Host execution space) hydro solver — the CPU twin of the AOT
//! artifacts: ideal-gas Euler equations, PLM (MC limiter) reconstruction on
//! primitives, HLLE Riemann solver, unsplit flux-divergence RK stage.
//!
//! Numerics mirror `python/compile/kernels/ref.py` operation-for-operation
//! in f32; Host-vs-Device equivalence is pinned by
//! rust/tests/device_equivalence.rs.  Unlike the monolithic device stage,
//! the native path exposes *fluxes* explicitly, which is what enables flux
//! correction at fine-coarse boundaries (paper Sec. 3.7).

use crate::mesh::IndexShape;
use crate::{Real, NHYDRO};

pub const IDN: usize = 0;
pub const IM1: usize = 1;
pub const IM2: usize = 2;
pub const IM3: usize = 3;
pub const IEN: usize = 4;
pub const IVX: usize = 1;
pub const IVY: usize = 2;
pub const IVZ: usize = 3;
pub const IPR: usize = 4;

pub const PRESSURE_FLOOR: Real = 1.0e-10;
pub const DENSITY_FLOOR: Real = 1.0e-10;

/// RK stage coefficients: u_new = g0*u0 + g1*u + beta*dt*L(u).
#[derive(Debug, Clone, Copy)]
pub struct StageCoeffs {
    pub g0: Real,
    pub g1: Real,
    pub beta: Real,
}

/// Two-stage RK2 as in PARTHENON-HYDRO.
pub const RK2_STAGES: [StageCoeffs; 2] = [
    StageCoeffs { g0: 0.0, g1: 1.0, beta: 1.0 },
    StageCoeffs { g0: 0.5, g1: 0.5, beta: 0.5 },
];

/// Flux storage for one block: one face-centered array per direction.
/// Direction d has interior extent +1 along d, interior extent elsewhere.
#[derive(Debug, Clone, Default)]
pub struct FluxArrays {
    pub f: [Vec<Real>; 3],
    pub dims: [[usize; 3]; 3], // per direction: (nx_f, ny_f, nz_f)
}

impl FluxArrays {
    pub fn new(shape: &IndexShape) -> Self {
        let mut fa = FluxArrays::default();
        for d in 0..shape.dim {
            let mut dims = [shape.n[0], shape.n[1], shape.n[2]];
            dims[d] += 1;
            fa.dims[d] = dims;
            fa.f[d] = vec![0.0; NHYDRO * dims[0] * dims[1] * dims[2]];
        }
        fa
    }

    /// Flux element (v, k, j, i) for direction d (face-indexed along d).
    #[inline]
    pub fn idx(&self, d: usize, v: usize, k: usize, j: usize, i: usize) -> usize {
        let [nx, ny, _] = self.dims[d];
        ((v * self.dims[d][2] + k) * ny + j) * nx + i
    }
}

/// Reusable scratch to keep the hot loop allocation-free.
#[derive(Debug, Default)]
pub struct Scratch {
    w: Vec<Real>,
    dq: Vec<Real>,
}

impl Scratch {
    pub fn ensure(&mut self, shape: &IndexShape) {
        let n = NHYDRO * shape.ncells_total();
        if self.w.len() != n {
            self.w = vec![0.0; n];
            self.dq = vec![0.0; n];
        }
    }
}

/// Conserved -> primitive over the whole (ghosted) array.
pub fn primitives(u: &[Real], shape: &IndexShape, gamma: Real, w: &mut [Real]) {
    let n = shape.ncells_total();
    for c in 0..n {
        let rho = u[IDN * n + c].max(DENSITY_FLOOR);
        let vx = u[IM1 * n + c] / rho;
        let vy = u[IM2 * n + c] / rho;
        let vz = u[IM3 * n + c] / rho;
        let ke = 0.5 * rho * (vx * vx + vy * vy + vz * vz);
        let p = ((gamma - 1.0) * (u[IEN * n + c] - ke)).max(PRESSURE_FLOOR);
        w[IDN * n + c] = rho;
        w[IVX * n + c] = vx;
        w[IVY * n + c] = vy;
        w[IVZ * n + c] = vz;
        w[IPR * n + c] = p;
    }
}

#[inline]
fn mc_limit(dqm: Real, dqp: Real) -> Real {
    if dqm * dqp > 0.0 {
        let avg = 0.5 * (dqm + dqp);
        let lim = (2.0 * dqm.abs().min(dqp.abs())).min(avg.abs());
        lim * avg.signum()
    } else {
        0.0
    }
}

/// MC-limited slopes of `w` along direction d.
///
/// Only the cells the reconstruction actually consumes are computed:
/// along d the stencil needs [g-1, g+n+1); tangentially only the interior
/// rows are read — skipping ghost rows cuts ~1/3 of the work on small
/// blocks (see EXPERIMENTS.md §Perf).
fn slopes(w: &[Real], shape: &IndexShape, d: usize, dq: &mut [Real]) {
    let n = shape.ncells_total();
    let stride = match d {
        0 => 1usize,
        1 => shape.nt(0),
        _ => shape.nt(0) * shape.nt(1),
    };
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));
    let g = crate::NGHOST;
    // per-axis [lo, hi) ranges: stencil extent along d, interior tangentially
    let range = |a: usize| -> (usize, usize) {
        if a == d {
            (shape.is_(a).saturating_sub(1).max(1), (shape.ie(a) + 1).min(shape.nt(a) - 1))
        } else {
            (shape.is_(a), shape.ie(a))
        }
    };
    let _ = g;
    let (ilo, ihi) = range(0);
    let (jlo, jhi) = range(1);
    let (klo, khi) = range(2);
    for v in 0..NHYDRO {
        for k in klo..khi {
            for j in jlo..jhi {
                let row = v * n + (k * nt1 + j) * nt0;
                for c in row + ilo..row + ihi {
                    let dqm = w[c] - w[c - stride];
                    let dqp = w[c + stride] - w[c];
                    dq[c] = mc_limit(dqm, dqp);
                }
            }
        }
    }
}

#[inline]
fn sound_speed(rho: Real, p: Real, gamma: Real) -> Real {
    (gamma * p / rho).sqrt()
}

/// HLLE flux for primitive states wl/wr ([5]) along direction d.
#[inline]
pub fn hlle(wl: &[Real; 5], wr: &[Real; 5], d: usize, gamma: Real) -> [Real; 5] {
    let cl = sound_speed(wl[IDN], wl[IPR], gamma);
    let cr = sound_speed(wr[IDN], wr[IPR], gamma);
    let vnl = wl[1 + d];
    let vnr = wr[1 + d];
    let sl = (vnl - cl).min(vnr - cr).min(0.0);
    let sr = (vnl + cl).max(vnr + cr).max(0.0);

    let cons = |w: &[Real; 5]| -> [Real; 5] {
        let ke = 0.5 * w[IDN] * (w[IVX] * w[IVX] + w[IVY] * w[IVY] + w[IVZ] * w[IVZ]);
        [
            w[IDN],
            w[IDN] * w[IVX],
            w[IDN] * w[IVY],
            w[IDN] * w[IVZ],
            w[IPR] / (gamma - 1.0) + ke,
        ]
    };
    let flux = |w: &[Real; 5]| -> [Real; 5] {
        let vn = w[1 + d];
        let e = {
            let ke =
                0.5 * w[IDN] * (w[IVX] * w[IVX] + w[IVY] * w[IVY] + w[IVZ] * w[IVZ]);
            w[IPR] / (gamma - 1.0) + ke
        };
        let mut f = [
            w[IDN] * vn,
            w[IDN] * w[IVX] * vn,
            w[IDN] * w[IVY] * vn,
            w[IDN] * w[IVZ] * vn,
            (e + w[IPR]) * vn,
        ];
        f[1 + d] += w[IPR];
        f
    };

    let ul = cons(wl);
    let ur = cons(wr);
    let fl = flux(wl);
    let fr = flux(wr);
    let denom = sr - sl;
    let mut out = [0.0; 5];
    for v in 0..5 {
        out[v] = (sr * fl[v] - sl * fr[v] + sl * sr * (ur[v] - ul[v])) / denom;
    }
    out
}

/// Compute HLLE fluxes at every interior face, all directions.
pub fn compute_fluxes(
    u: &[Real],
    shape: &IndexShape,
    gamma: Real,
    fx: &mut FluxArrays,
    scratch: &mut Scratch,
) {
    scratch.ensure(shape);
    let n = shape.ncells_total();
    // w reused across directions
    primitives(u, shape, gamma, &mut scratch.w);
    let g = crate::NGHOST;
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));

    for d in 0..shape.dim {
        slopes(&scratch.w, shape, d, &mut scratch.dq);
        let stride = match d {
            0 => 1usize,
            1 => nt0,
            _ => nt0 * nt1,
        };
        let [nfx, nfy, nfz] = fx.dims[d];
        for k in 0..nfz {
            for j in 0..nfy {
                for i in 0..nfx {
                    // face f between cells (c - stride) and c, where the
                    // face index maps to ghosted cell coordinates:
                    let ci = if d == 0 { i + g } else { i + shape.is_(0) };
                    let cj = if d == 1 { j + g } else { j + shape.is_(1) };
                    let ck = if d == 2 { k + g } else { k + shape.is_(2) };
                    let c = (ck * nt1 + cj) * nt0 + ci;
                    let cm = c - stride;
                    let mut wl = [0.0; 5];
                    let mut wr = [0.0; 5];
                    for v in 0..NHYDRO {
                        wl[v] = scratch.w[v * n + cm] + 0.5 * scratch.dq[v * n + cm];
                        wr[v] = scratch.w[v * n + c] - 0.5 * scratch.dq[v * n + c];
                    }
                    let f = hlle(&wl, &wr, d, gamma);
                    for v in 0..NHYDRO {
                        let ix = fx.idx(d, v, k, j, i);
                        fx.f[d][ix] = f[v];
                    }
                }
            }
        }
    }
}

/// Apply the stage combine: u_new = g0*u0 + g1*u + beta*dt*(-div F) on the
/// interior. Ghosts of `out` are copied from `u`.
pub fn apply_stage(
    u: &[Real],
    u0: &[Real],
    fx: &FluxArrays,
    shape: &IndexShape,
    co: StageCoeffs,
    dt: Real,
    dx: [Real; 3],
    out: &mut [Real],
) {
    out.copy_from_slice(u);
    let n = shape.ncells_total();
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));
    let inv = [1.0 / dx[0], 1.0 / dx[1], 1.0 / dx[2]];
    for v in 0..NHYDRO {
        for kk in 0..shape.n[2] {
            for jj in 0..shape.n[1] {
                for ii in 0..shape.n[0] {
                    let mut div = 0.0;
                    for d in 0..shape.dim {
                        let (fi, fj, fk) = (ii, jj, kk);
                        let lo = fx.f[d][fx.idx(d, v, fk, fj, fi)];
                        let hi = match d {
                            0 => fx.f[d][fx.idx(d, v, fk, fj, fi + 1)],
                            1 => fx.f[d][fx.idx(d, v, fk, fj + 1, fi)],
                            _ => fx.f[d][fx.idx(d, v, fk + 1, fj, fi)],
                        };
                        div += (hi - lo) * inv[d];
                    }
                    let c = ((kk + shape.is_(2)) * nt1 + (jj + shape.is_(1))) * nt0
                        + ii + shape.is_(0);
                    out[v * n + c] =
                        co.g0 * u0[v * n + c] + co.g1 * u[v * n + c] - co.beta * dt * div;
                }
            }
        }
    }
}

/// One full RK stage (fluxes + combine) — the native analog of the `stage`
/// artifact.
#[allow(clippy::too_many_arguments)]
pub fn stage(
    u: &[Real],
    u0: &[Real],
    shape: &IndexShape,
    co: StageCoeffs,
    dt: Real,
    dx: [Real; 3],
    gamma: Real,
    fx: &mut FluxArrays,
    scratch: &mut Scratch,
    out: &mut [Real],
) {
    compute_fluxes(u, shape, gamma, fx, scratch);
    apply_stage(u, u0, fx, shape, co, dt, dx, out);
}

/// Per-block CFL limit min_d(dx_d / (|v_d| + c)) over interior cells.
pub fn min_dt(u: &[Real], shape: &IndexShape, dx: [Real; 3], gamma: Real) -> Real {
    let n = shape.ncells_total();
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));
    let mut dt = Real::INFINITY;
    for k in shape.is_(2)..shape.ie(2) {
        for j in shape.is_(1)..shape.ie(1) {
            for i in shape.is_(0)..shape.ie(0) {
                let c = (k * nt1 + j) * nt0 + i;
                let rho = u[IDN * n + c].max(DENSITY_FLOOR);
                let vx = u[IM1 * n + c] / rho;
                let vy = u[IM2 * n + c] / rho;
                let vz = u[IM3 * n + c] / rho;
                let ke = 0.5 * rho * (vx * vx + vy * vy + vz * vz);
                let p = ((gamma - 1.0) * (u[IEN * n + c] - ke)).max(PRESSURE_FLOOR);
                let cs = sound_speed(rho, p, gamma);
                dt = dt.min(dx[0] / (vx.abs() + cs));
                if shape.dim >= 2 {
                    dt = dt.min(dx[1] / (vy.abs() + cs));
                }
                if shape.dim >= 3 {
                    dt = dt.min(dx[2] / (vz.abs() + cs));
                }
            }
        }
    }
    dt
}

/// Conserved state from primitive values (problem generators).
pub fn cons_from_prim(w: [Real; 5], gamma: Real) -> [Real; 5] {
    let ke = 0.5 * w[IDN] * (w[IVX] * w[IVX] + w[IVY] * w[IVY] + w[IVZ] * w[IVZ]);
    [
        w[IDN],
        w[IDN] * w[IVX],
        w[IDN] * w[IVY],
        w[IDN] * w[IVZ],
        w[IPR] / (gamma - 1.0) + ke,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    fn uniform_state(shape: &IndexShape, gamma: Real) -> Vec<Real> {
        let n = shape.ncells_total();
        let mut u = vec![0.0; NHYDRO * n];
        for c in 0..n {
            u[IDN * n + c] = 1.0;
            u[IEN * n + c] = 1.0 / (gamma - 1.0);
        }
        u
    }

    fn random_state(shape: &IndexShape, gamma: Real, seed: u64) -> Vec<Real> {
        let mut rng = XorShift::new(seed);
        let n = shape.ncells_total();
        let mut u = uniform_state(shape, gamma);
        for c in 0..n {
            u[IDN * n + c] += 0.2 * (rng.next_f32() - 0.5);
            u[IM1 * n + c] += 0.2 * (rng.next_f32() - 0.5);
            u[IM2 * n + c] += 0.2 * (rng.next_f32() - 0.5);
            u[IEN * n + c] += 0.2 * rng.next_f32();
        }
        u
    }

    #[test]
    fn uniform_state_is_stationary() {
        let shape = IndexShape::new(2, [8, 8, 1]);
        let gamma = 1.4;
        let u = uniform_state(&shape, gamma);
        let mut fx = FluxArrays::new(&shape);
        let mut sc = Scratch::default();
        let mut out = vec![0.0; u.len()];
        stage(
            &u,
            &u,
            &shape,
            RK2_STAGES[0],
            0.01,
            [0.1, 0.1, 0.1],
            gamma,
            &mut fx,
            &mut sc,
            &mut out,
        );
        for (a, b) in u.iter().zip(out.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn beta_zero_is_identity_with_g_combination() {
        let shape = IndexShape::new(3, [4, 4, 4]);
        let gamma = 1.4;
        let u = random_state(&shape, gamma, 3);
        let mut fx = FluxArrays::new(&shape);
        let mut sc = Scratch::default();
        let mut out = vec![0.0; u.len()];
        let co = StageCoeffs { g0: 0.0, g1: 1.0, beta: 0.0 };
        stage(&u, &u, &shape, co, 0.1, [0.1; 3], gamma, &mut fx, &mut sc, &mut out);
        assert_eq!(u, out);
    }

    #[test]
    fn interior_conservation_with_periodic_ghosts() {
        let shape = IndexShape::new(2, [8, 8, 1]);
        let gamma = 1.4;
        let mut u = random_state(&shape, gamma, 7);
        // impose periodic ghosts
        let n = shape.ncells_total();
        let g = crate::NGHOST;
        let (nt0, nt1) = (shape.nt(0), shape.nt(1));
        let wrap = |x: usize, ni: usize| ((x as i64 - g as i64).rem_euclid(ni as i64)) as usize + g;
        for v in 0..NHYDRO {
            for j in 0..nt1 {
                for i in 0..nt0 {
                    let src = v * n + (wrap(j, 8) * nt0 + wrap(i, 8));
                    let dst = v * n + (j * nt0 + i);
                    let val = u[src];
                    u[dst] = val;
                }
            }
        }
        let mut fx = FluxArrays::new(&shape);
        let mut sc = Scratch::default();
        let mut out = vec![0.0; u.len()];
        stage(
            &u,
            &u,
            &shape,
            RK2_STAGES[0],
            1e-3,
            [0.05, 0.05, 0.05],
            gamma,
            &mut fx,
            &mut sc,
            &mut out,
        );
        for v in [IDN, IM1, IEN] {
            let mut before = 0.0f64;
            let mut after = 0.0f64;
            for j in g..g + 8 {
                for i in g..g + 8 {
                    before += u[v * n + j * nt0 + i] as f64;
                    after += out[v * n + j * nt0 + i] as f64;
                }
            }
            assert!(
                (before - after).abs() <= 2e-5 * before.abs().max(1.0),
                "var {v}: {before} -> {after}"
            );
        }
    }

    #[test]
    fn dt_positive_and_velocity_sensitive() {
        let shape = IndexShape::new(3, [4, 4, 4]);
        let gamma = 1.4;
        let mut u = uniform_state(&shape, gamma);
        let dt0 = min_dt(&u, &shape, [0.1; 3], gamma);
        assert!(dt0 > 0.0 && dt0.is_finite());
        let n = shape.ncells_total();
        for c in 0..n {
            u[IM1 * n + c] = 5.0;
            u[IEN * n + c] += 0.5 * 25.0;
        }
        let dt1 = min_dt(&u, &shape, [0.1; 3], gamma);
        assert!(dt1 < dt0);
    }

    #[test]
    fn hlle_upwinds_supersonic() {
        // supersonic flow to the right: flux must equal left analytic flux
        let gamma = 1.4;
        let wl = [1.0, 5.0, 0.0, 0.0, 1.0];
        let wr = [0.5, 5.0, 0.0, 0.0, 0.8];
        let f = hlle(&wl, &wr, 0, gamma);
        // analytic left flux
        let e = wl[IPR] / (gamma - 1.0) + 0.5 * wl[IDN] * wl[IVX] * wl[IVX];
        assert!((f[IDN] - wl[IDN] * wl[IVX]).abs() < 1e-5);
        assert!((f[IM1] - (wl[IDN] * wl[IVX] * wl[IVX] + wl[IPR])).abs() < 1e-4);
        assert!((f[IEN] - (e + wl[IPR]) * wl[IVX]).abs() < 1e-4);
    }

    #[test]
    fn mirror_symmetry_x() {
        let shape = IndexShape::new(2, [8, 4, 1]);
        let gamma = 1.4;
        let u = random_state(&shape, gamma, 11);
        let n = shape.ncells_total();
        let (nt0, nt1) = (shape.nt(0), shape.nt(1));
        // mirrored state
        let mut um = u.clone();
        for v in 0..NHYDRO {
            for j in 0..nt1 {
                for i in 0..nt0 {
                    let s = v * n + j * nt0 + (nt0 - 1 - i);
                    um[v * n + j * nt0 + i] = if v == IM1 { -u[s] } else { u[s] };
                }
            }
        }
        let mut fx = FluxArrays::new(&shape);
        let mut sc = Scratch::default();
        let mut out = vec![0.0; u.len()];
        let mut outm = vec![0.0; u.len()];
        let co = RK2_STAGES[0];
        stage(&u, &u, &shape, co, 1e-3, [0.1; 3], gamma, &mut fx, &mut sc, &mut out);
        stage(&um, &um, &shape, co, 1e-3, [0.1; 3], gamma, &mut fx, &mut sc, &mut outm);
        for v in 0..NHYDRO {
            for j in 0..nt1 {
                for i in 0..nt0 {
                    let a = out[v * n + j * nt0 + i];
                    let s = v * n + j * nt0 + (nt0 - 1 - i);
                    let b = if v == IM1 { -outm[s] } else { outm[s] };
                    assert!(
                        (a - b).abs() < 1e-5 * a.abs().max(1.0),
                        "v{v} j{j} i{i}: {a} vs {b}"
                    );
                }
            }
        }
    }
}
