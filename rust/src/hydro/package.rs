//! The hydro package: registers the conserved/primitive fields, params, and
//! the package hooks (dt estimate, derived fill, AMR criterion).

use super::native;
use crate::config::ParameterInput;
use crate::mesh::{AmrFlag, Coords, IndexShape};
use crate::vars::{
    MeshBlockData, Metadata, MetadataFlag, Package, ParamValue, StateDescriptor,
};
use crate::{Real, NHYDRO};

/// Canonical variable names.
pub const CONS: &str = "cons";
pub const PRIM: &str = "prim";

/// AMR tagging criterion for hydro.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefineCriterion {
    None,
    /// Max relative density gradient.
    DensityGradient,
    /// Max relative pressure gradient.
    PressureGradient,
}

pub struct HydroPackage {
    desc: StateDescriptor,
    pub gamma: Real,
    pub cfl: Real,
    pub criterion: RefineCriterion,
    pub refine_tol: Real,
    pub derefine_tol: Real,
}

impl HydroPackage {
    /// The package Initialize function (paper Listing 5 analog).
    pub fn initialize(pin: &mut ParameterInput) -> Self {
        let gamma = pin.real_or("hydro", "gamma", 5.0 / 3.0) as Real;
        let cfl = pin.real_or("hydro", "cfl", 0.3) as Real;
        let crit = match pin.str_or("hydro", "refine_criterion", "none").as_str() {
            "density_gradient" => RefineCriterion::DensityGradient,
            "pressure_gradient" => RefineCriterion::PressureGradient,
            _ => RefineCriterion::None,
        };
        let refine_tol = pin.real_or("hydro", "refine_tol", 0.3) as Real;
        let derefine_tol = pin.real_or("hydro", "derefine_tol", 0.03) as Real;

        let mut desc = StateDescriptor::new("hydro");
        desc.add_field(
            CONS,
            Metadata::new(&[
                MetadataFlag::Cell,
                MetadataFlag::Independent,
                MetadataFlag::FillGhost,
                MetadataFlag::WithFluxes,
                MetadataFlag::Provides,
            ])
            .with_shape(vec![NHYDRO]),
        );
        desc.add_field(
            PRIM,
            Metadata::new(&[
                MetadataFlag::Cell,
                MetadataFlag::Derived,
                MetadataFlag::Provides,
            ])
            .with_shape(vec![NHYDRO]),
        );
        desc.params.add("gamma", ParamValue::Real(gamma as f64));
        desc.params.add("cfl", ParamValue::Real(cfl as f64));

        HydroPackage {
            desc,
            gamma,
            cfl,
            criterion: crit,
            refine_tol,
            derefine_tol,
        }
    }

    /// Max relative central-difference gradient of one component over the
    /// interior (the AMR indicator).
    fn max_rel_gradient(data: &MeshBlockData, shape: &IndexShape, comp: usize) -> Real {
        let Ok(arr) = data.get(CONS) else { return 0.0 };
        let u = arr.as_slice();
        let n = shape.ncells_total();
        let (nt0, nt1) = (shape.nt(0), shape.nt(1));
        let strides = [1usize, nt0, nt0 * nt1];
        let mut gmax: Real = 0.0;
        for k in shape.is_(2)..shape.ie(2) {
            for j in shape.is_(1)..shape.ie(1) {
                for i in shape.is_(0)..shape.ie(0) {
                    let c = comp * n + (k * nt1 + j) * nt0 + i;
                    let q = u[c].abs().max(1e-12);
                    for (d, &s) in strides.iter().enumerate().take(shape.dim) {
                        let _ = d;
                        let g = 0.5 * (u[c + s] - u[c - s]).abs() / q;
                        gmax = gmax.max(g);
                    }
                }
            }
        }
        gmax
    }
}

impl Package for HydroPackage {
    fn descriptor(&self) -> &StateDescriptor {
        &self.desc
    }

    fn check_refinement(&self, data: &MeshBlockData, _coords: &Coords) -> AmrFlag {
        if self.criterion == RefineCriterion::None {
            return AmrFlag::Same;
        }
        let Some(shape) = data.shape else { return AmrFlag::Same };
        let comp = match self.criterion {
            RefineCriterion::DensityGradient => native::IDN,
            RefineCriterion::PressureGradient => native::IEN,
            RefineCriterion::None => unreachable!(),
        };
        let g = Self::max_rel_gradient(data, &shape, comp);
        if g > self.refine_tol {
            AmrFlag::Refine
        } else if g < self.derefine_tol {
            AmrFlag::Derefine
        } else {
            AmrFlag::Same
        }
    }

    fn estimate_dt(&self, data: &MeshBlockData, coords: &Coords) -> f64 {
        let Some(shape) = data.shape else { return f64::INFINITY };
        let Ok(arr) = data.get(CONS) else { return f64::INFINITY };
        let dx = [coords.dx[0] as Real, coords.dx[1] as Real, coords.dx[2] as Real];
        (self.cfl * native::min_dt(arr.as_slice(), &shape, dx, self.gamma)) as f64
    }

    fn fill_derived(&self, data: &mut MeshBlockData, _coords: &Coords) {
        let Some(shape) = data.shape else { return };
        if data.index_of(PRIM).is_none() {
            return;
        }
        let Ok((cons, prim)) = data.get2_mut(CONS, PRIM) else { return };
        native::primitives(cons.as_slice(), &shape, self.gamma, prim.as_mut_slice());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::LogicalLocation;
    use crate::mesh::RegionSize;
    use crate::vars::resolve_packages;

    fn make_data() -> (MeshBlockData, Coords) {
        let mut pin = ParameterInput::new();
        let pkg = HydroPackage::initialize(&mut pin);
        let fields = resolve_packages(&[pkg.descriptor()]).unwrap();
        let shape = IndexShape::new(2, [8, 8, 1]);
        let data = MeshBlockData::from_fields(&fields, shape);
        let coords = Coords::from_location(
            &LogicalLocation::new(0, 0, 0, 0),
            [8, 8, 1],
            [1, 1, 1],
            &RegionSize::unit_cube(),
            2,
            crate::NGHOST,
        );
        (data, coords)
    }

    #[test]
    fn registers_cons_and_prim() {
        let (data, _) = make_data();
        assert_eq!(data.get(CONS).unwrap().dims()[0], NHYDRO);
        assert_eq!(data.get(PRIM).unwrap().dims()[0], NHYDRO);
    }

    #[test]
    fn fill_derived_computes_primitives() {
        let (mut data, coords) = make_data();
        let mut pin = ParameterInput::new();
        let pkg = HydroPackage::initialize(&mut pin);
        {
            let cons = data.get_mut(CONS).unwrap();
            let n = cons.dims()[1] * cons.dims()[2] * cons.dims()[3];
            for c in 0..n {
                cons.as_mut_slice()[c] = 2.0; // rho
                cons.as_mut_slice()[4 * n + c] = 5.0; // E
            }
        }
        pkg.fill_derived(&mut data, &coords);
        let prim = data.get(PRIM).unwrap();
        assert!((prim.get(0, 0, 2, 2) - 2.0).abs() < 1e-6);
        let p_expect = (pkg.gamma - 1.0) * 5.0;
        assert!((prim.get(4, 0, 2, 2) - p_expect).abs() < 1e-5);
    }

    #[test]
    fn dt_estimate_finite_positive() {
        let (mut data, coords) = make_data();
        let mut pin = ParameterInput::new();
        let pkg = HydroPackage::initialize(&mut pin);
        {
            let cons = data.get_mut(CONS).unwrap();
            let n = cons.dims()[1] * cons.dims()[2] * cons.dims()[3];
            for c in 0..n {
                cons.as_mut_slice()[c] = 1.0;
                cons.as_mut_slice()[4 * n + c] = 2.5;
            }
        }
        let dt = pkg.estimate_dt(&data, &coords);
        assert!(dt > 0.0 && dt.is_finite());
    }

    #[test]
    fn refinement_flags_on_sharp_gradient() {
        let (mut data, coords) = make_data();
        let mut pin = ParameterInput::new();
        pin.set("hydro", "refine_criterion", "density_gradient");
        let pkg = HydroPackage::initialize(&mut pin);
        {
            let cons = data.get_mut(CONS).unwrap();
            let dims = cons.dims();
            let n = dims[1] * dims[2] * dims[3];
            for c in 0..n {
                cons.as_mut_slice()[c] = 1.0;
                cons.as_mut_slice()[4 * n + c] = 2.5;
            }
        }
        assert_eq!(pkg.check_refinement(&data, &coords), AmrFlag::Derefine);
        {
            let cons = data.get_mut(CONS).unwrap();
            // density step in the middle
            for j in 0..cons.dims()[2] {
                for i in 6..cons.dims()[3] {
                    cons.set(0, 0, j, i, 5.0);
                }
            }
        }
        assert_eq!(pkg.check_refinement(&data, &coords), AmrFlag::Refine);
    }
}
