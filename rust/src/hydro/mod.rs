//! PARTHENON-HYDRO: the paper's miniapp (Sec. 4.1) as a package —
//! compressible Euler equations, RK2 + PLM + HLLE, on 1/2/3D (static or
//! adaptive) meshes, with a native (Host) solver and a Device path through
//! the AOT artifacts.

pub mod native;
mod package;
pub mod problems;

pub use package::{HydroPackage, CONS, PRIM};
