//! Problem generators for PARTHENON-HYDRO (paper Sec. 4.1): linear wave
//! (convergence testing), spherical blast wave, Kelvin-Helmholtz
//! instability, plus a uniform-flow generator for benchmarks.

use super::native::{cons_from_prim, IDN, IEN, IM1, IM2, IM3};
use super::package::CONS;
use crate::config::ParameterInput;
use crate::error::Result;
use crate::mesh::MeshBlock;
use crate::Real;

/// Known problem generators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Problem {
    LinearWave,
    Blast,
    KelvinHelmholtz,
    Uniform,
}

impl Problem {
    pub fn parse(s: &str) -> Option<Problem> {
        match s {
            "linear_wave" => Some(Problem::LinearWave),
            "blast" => Some(Problem::Blast),
            "kh" | "kelvin_helmholtz" => Some(Problem::KelvinHelmholtz),
            "uniform" => Some(Problem::Uniform),
            _ => None,
        }
    }
}

/// Fill a block's conserved state from a primitive-valued function of the
/// physical cell-center position (ghosts included; they are overwritten by
/// the initial exchange anyway, but a full fill keeps everything defined).
pub fn init_block(
    mb: &mut MeshBlock,
    gamma: Real,
    f: impl Fn([f64; 3]) -> [Real; 5],
) -> Result<()> {
    let shape = mb.shape;
    let coords = mb.coords;
    let arr = mb.data.get_mut(CONS)?;
    let n = shape.ncells_total();
    let (nt0, nt1, nt2) = (shape.nt(0), shape.nt(1), shape.nt(2));
    for k in 0..nt2 {
        for j in 0..nt1 {
            for i in 0..nt0 {
                let x = [coords.center(0, i), coords.center(1, j), coords.center(2, k)];
                let u = cons_from_prim(f(x), gamma);
                let c = (k * nt1 + j) * nt0 + i;
                let s = arr.as_mut_slice();
                s[IDN * n + c] = u[0];
                s[IM1 * n + c] = u[1];
                s[IM2 * n + c] = u[2];
                s[IM3 * n + c] = u[3];
                s[IEN * n + c] = u[4];
            }
        }
    }
    Ok(())
}

/// Dispatch a problem generator using its `<problem>` input block.
pub fn generate(problem: Problem, mb: &mut MeshBlock, pin: &mut ParameterInput, gamma: Real) -> Result<()> {
    match problem {
        Problem::LinearWave => linear_wave(mb, pin, gamma),
        Problem::Blast => blast(mb, pin, gamma),
        Problem::KelvinHelmholtz => kelvin_helmholtz(mb, pin, gamma),
        Problem::Uniform => uniform(mb, pin, gamma),
    }
}

/// Acoustic linear wave along x: exact solution translates at the sound
/// speed, so the L1 error after one period measures convergence order.
pub fn linear_wave(mb: &mut MeshBlock, pin: &mut ParameterInput, gamma: Real) -> Result<()> {
    let amp = pin.real_or("problem", "amp", 1e-3) as Real;
    let rho0 = pin.real_or("problem", "rho0", 1.0) as Real;
    let p0 = pin.real_or("problem", "p0", 1.0 / (gamma as f64)) as Real;
    let wavelength = pin.real_or("problem", "wavelength", 1.0);
    let cs = (gamma * p0 / rho0).sqrt();
    let k = (2.0 * std::f64::consts::PI / wavelength) as Real;
    init_block(mb, gamma, |x| {
        let s = (k * x[0] as Real).sin();
        [
            rho0 * (1.0 + amp * s),
            cs * amp * s,
            0.0,
            0.0,
            p0 * (1.0 + gamma * amp * s),
        ]
    })
}

/// Exact (linearized) solution of the linear wave at time t (for error
/// measurement by examples/tests).
pub fn linear_wave_exact(
    x: f64,
    t: f64,
    gamma: Real,
    amp: Real,
    rho0: Real,
    p0: Real,
    wavelength: f64,
) -> [Real; 5] {
    let cs = (gamma * p0 / rho0).sqrt();
    let k = 2.0 * std::f64::consts::PI / wavelength;
    let s = ((k * (x - cs as f64 * t)) as Real).sin();
    [
        rho0 * (1.0 + amp * s),
        cs * amp * s,
        0.0,
        0.0,
        p0 * (1.0 + gamma * amp * s),
    ]
}

/// Spherical blast wave: over-pressured region at the domain center.
pub fn blast(mb: &mut MeshBlock, pin: &mut ParameterInput, gamma: Real) -> Result<()> {
    let p_in = pin.real_or("problem", "p_in", 10.0) as Real;
    let p_out = pin.real_or("problem", "p_out", 0.1) as Real;
    let rho = pin.real_or("problem", "rho", 1.0) as Real;
    let radius = pin.real_or("problem", "radius", 0.1);
    let cx = pin.real_or("problem", "x0", 0.5);
    let cy = pin.real_or("problem", "y0", 0.5);
    let cz = pin.real_or("problem", "z0", 0.5);
    let dim = mb.shape.dim;
    init_block(mb, gamma, |x| {
        let mut r2 = (x[0] - cx) * (x[0] - cx);
        if dim >= 2 {
            r2 += (x[1] - cy) * (x[1] - cy);
        }
        if dim >= 3 {
            r2 += (x[2] - cz) * (x[2] - cz);
        }
        let p = if r2.sqrt() < radius { p_in } else { p_out };
        [rho, 0.0, 0.0, 0.0, p]
    })
}

/// Kelvin-Helmholtz instability (2D): shear layers with a density contrast
/// and a sinusoidal transverse seed — the paper's AMR demo problem.
pub fn kelvin_helmholtz(mb: &mut MeshBlock, pin: &mut ParameterInput, gamma: Real) -> Result<()> {
    let vflow = pin.real_or("problem", "vflow", 0.5) as Real;
    let drho = pin.real_or("problem", "drho", 1.0) as Real;
    let amp = pin.real_or("problem", "amp", 0.01) as Real;
    let p0 = pin.real_or("problem", "p0", 2.5) as Real;
    let a = pin.real_or("problem", "shear_width", 0.02);
    let sigma = pin.real_or("problem", "seed_width", 0.2);
    init_block(mb, gamma, |x| {
        // two shear layers at y = 0.25 and y = 0.75 (periodic unit square)
        let y = x[1];
        let prof = |y0: f64| ((y - y0) / a).tanh() as Real;
        let shear = 0.5 * (prof(0.25) - prof(0.75)); // +1 in the band
        let rho = 1.0 + 0.5 * drho * (1.0 + shear);
        let vx = vflow * shear;
        let seed = amp
            * (2.0 * std::f64::consts::PI * x[0]).sin() as Real
            * ((-((y - 0.25) / sigma).powi(2)).exp() + (-((y - 0.75) / sigma).powi(2)).exp())
                as Real;
        [rho, vx, seed, 0.0, p0]
    })
}

/// Uniform flow — the benchmark workload (every cell costs the same, so
/// zone-cycles/s is workload-independent, like the paper's setup).
pub fn uniform(mb: &mut MeshBlock, pin: &mut ParameterInput, gamma: Real) -> Result<()> {
    let rho = pin.real_or("problem", "rho", 1.0) as Real;
    let vx = pin.real_or("problem", "vx", 0.1) as Real;
    let vy = pin.real_or("problem", "vy", 0.05) as Real;
    let p = pin.real_or("problem", "p", 1.0) as Real;
    init_block(mb, gamma, |_| [rho, vx, vy, 0.0, p])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{Mesh, MeshConfig};
    use crate::vars::resolve_packages;
    use crate::vars::Package;

    fn mesh_2d() -> (Mesh, ParameterInput) {
        let mut pin = ParameterInput::from_str(
            "<parthenon/mesh>\nnx1 = 16\nnx2 = 16\n<parthenon/meshblock>\nnx1 = 8\nnx2 = 8\n",
        )
        .unwrap();
        let cfg = MeshConfig::from_params(&mut pin).unwrap();
        let pkg = crate::hydro::HydroPackage::initialize(&mut pin);
        let fields = resolve_packages(&[pkg.descriptor()]).unwrap();
        (Mesh::build(cfg, fields, 0, 1), pin)
    }

    #[test]
    fn generators_produce_positive_density_pressure() {
        let (mut mesh, mut pin) = mesh_2d();
        let gamma = 1.4;
        for prob in [
            Problem::LinearWave,
            Problem::Blast,
            Problem::KelvinHelmholtz,
            Problem::Uniform,
        ] {
            for mb in &mut mesh.blocks {
                generate(prob, mb, &mut pin, gamma).unwrap();
                let shape = mb.shape;
                let arr = mb.data.get(CONS).unwrap();
                let n = shape.ncells_total();
                for c in 0..n {
                    let rho = arr.as_slice()[c];
                    let e = arr.as_slice()[4 * n + c];
                    assert!(rho > 0.0, "{prob:?}: rho {rho}");
                    assert!(e > 0.0, "{prob:?}: E {e}");
                }
            }
        }
    }

    #[test]
    fn linear_wave_exact_is_initial_at_t0() {
        let gamma = 1.4f32;
        let w = linear_wave_exact(0.3, 0.0, gamma, 1e-3, 1.0, 1.0 / 1.4, 1.0);
        let s = (2.0 * std::f64::consts::PI * 0.3).sin() as f32;
        assert!((w[0] - (1.0 + 1e-3 * s)).abs() < 1e-6);
    }

    #[test]
    fn blast_has_overpressure_only_inside() {
        let (mut mesh, mut pin) = mesh_2d();
        let gamma = 1.4;
        let mb = &mut mesh.blocks[0];
        blast(mb, &mut pin, gamma).unwrap();
        // block 0 covers [0, 0.5)^2; center (0.5, 0.5) has the hot region
        let shape = mb.shape;
        let arr = mb.data.get(CONS).unwrap();
        let n = shape.ncells_total();
        // far corner cell (low x, low y) must be cold
        let c = shape.idx3(0, shape.is_(1), shape.is_(0));
        let e_cold = arr.as_slice()[4 * n + c];
        assert!(e_cold < 1.0);
    }

    #[test]
    fn problem_parse() {
        assert_eq!(Problem::parse("kh"), Some(Problem::KelvinHelmholtz));
        assert_eq!(Problem::parse("nope"), None);
    }
}
