//! One MeshBlock: location, coordinates, and its data container.

use std::collections::HashMap;

use super::coords::Coords;
use super::domain::IndexShape;
use super::logical_location::LogicalLocation;
use crate::particles::Swarm;
use crate::vars::MeshBlockData;

/// A MeshBlock — the unit of work, communication and distribution.
#[derive(Debug, Clone)]
pub struct MeshBlock {
    /// Global id = index of the leaf in Z-order (renumbered on regrid).
    pub gid: usize,
    pub loc: LogicalLocation,
    pub coords: Coords,
    pub shape: IndexShape,
    pub data: MeshBlockData,
    /// Particle swarms living on this block.
    pub swarms: HashMap<String, Swarm>,
    /// Load-balancing weight: an EWMA of measured per-cycle seconds,
    /// normalized so the GLOBAL mean is ~1.0 (fed by the host stage
    /// timings each cycle; see `HydroSim::update_block_costs`). Seeds the
    /// cost-weighted scheduler partition and `balance::assign_blocks`.
    pub cost: f64,
}

impl MeshBlock {
    /// Nominal cost before any cycle has been measured.
    pub const DEFAULT_COST: f64 = 1.0;

    /// Interior zone count (the paper's "zones" for zone-cycles/s).
    pub fn num_zones(&self) -> usize {
        self.shape.ncells_interior()
    }
}
