//! The block tree: the set of leaf MeshBlocks with neighbor finding,
//! refinement/derefinement, and 2:1 ("proper nesting") enforcement.
//!
//! Like Parthenon (paper Sec. 2.1) the tree is *rebuilt* on every regrid and
//! only leaves are materialized: there are no parent-child pointers, only a
//! sorted leaf list plus a hash index, so neighbor relationships are resolved
//! by logical-coordinate arithmetic.

use std::collections::{HashMap, HashSet};

use super::logical_location::LogicalLocation;
use crate::error::{Error, Result};

/// Per-block AMR decision, produced by package refinement criteria.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AmrFlag {
    Refine,
    Derefine,
    Same,
}

/// What lives on the other side of a block boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NeighborKind {
    /// Same-level neighbor leaf.
    SameLevel(LogicalLocation),
    /// Coarser (one level down) neighbor leaf.
    Coarser(LogicalLocation),
    /// Finer (one level up) neighbor leaves adjacent to the shared boundary,
    /// in Z-order.
    Finer(Vec<LogicalLocation>),
    /// Physical (non-periodic) domain boundary.
    Physical,
}

/// Fully resolved neighbor descriptor for one of the 26/8/2 offsets.
#[derive(Debug, Clone)]
pub struct NeighborInfo {
    /// Offset (ox1, ox2, ox3), each in {-1, 0, 1}.
    pub offset: [i32; 3],
    /// Canonical index of the offset in bufspec order.
    pub nbr_index: usize,
    pub kind: NeighborKind,
}

/// The leaf set of the block tree.
#[derive(Debug, Clone)]
pub struct BlockTree {
    /// Root-grid block counts per dimension.
    pub nrb: [i64; 3],
    pub dim: usize,
    pub periodic: [bool; 3],
    /// Leaves sorted by Morton key (Z-order) — the paper's distribution order.
    leaves: Vec<LogicalLocation>,
    index: HashMap<LogicalLocation, usize>,
}

impl BlockTree {
    /// Uniform tree: all `nrb` root blocks at level 0.
    pub fn uniform(nrb: [i64; 3], dim: usize, periodic: [bool; 3]) -> Self {
        let mut leaves = Vec::new();
        for k in 0..nrb[2] {
            for j in 0..nrb[1] {
                for i in 0..nrb[0] {
                    leaves.push(LogicalLocation::new(0, i, j, k));
                }
            }
        }
        Self::from_leaves(nrb, dim, periodic, leaves)
    }

    /// Build from an arbitrary leaf set (sorts and indexes it).
    pub fn from_leaves(
        nrb: [i64; 3],
        dim: usize,
        periodic: [bool; 3],
        mut leaves: Vec<LogicalLocation>,
    ) -> Self {
        leaves.sort_by_key(|l| l.morton());
        leaves.dedup();
        let index = leaves
            .iter()
            .enumerate()
            .map(|(i, l)| (*l, i))
            .collect();
        BlockTree { nrb, dim, periodic, leaves, index }
    }

    pub fn leaves(&self) -> &[LogicalLocation] {
        &self.leaves
    }

    pub fn nblocks(&self) -> usize {
        self.leaves.len()
    }

    /// Global block id (Z-order rank) of a leaf.
    pub fn gid_of(&self, loc: &LogicalLocation) -> Option<usize> {
        self.index.get(loc).copied()
    }

    pub fn contains(&self, loc: &LogicalLocation) -> bool {
        self.index.contains_key(loc)
    }

    pub fn max_level(&self) -> u8 {
        self.leaves.iter().map(|l| l.level).max().unwrap_or(0)
    }

    /// Number of blocks at level `lx[d]` along dimension d.
    fn width(&self, level: u8, d: usize) -> i64 {
        self.nrb[d] << level
    }

    /// Same-level logical coordinates of the neighbor at `offset`, with
    /// periodic wrapping. `None` if it falls outside a non-periodic boundary.
    pub fn neighbor_loc(
        &self,
        loc: &LogicalLocation,
        offset: [i32; 3],
    ) -> Option<LogicalLocation> {
        let mut lx = loc.lx;
        for d in 0..3 {
            if d >= self.dim {
                debug_assert_eq!(offset[d], 0);
                continue;
            }
            let w = self.width(loc.level, d);
            let mut v = lx[d] + offset[d] as i64;
            if v < 0 || v >= w {
                if self.periodic[d] {
                    v = v.rem_euclid(w);
                } else {
                    return None;
                }
            }
            lx[d] = v;
        }
        Some(LogicalLocation { level: loc.level, lx })
    }

    /// Resolve what occupies the neighbor position at `offset` from `loc`.
    ///
    /// Requires the tree to be properly nested (guaranteed by
    /// [`BlockTree::regrid`]): neighbors differ by at most one level.
    pub fn resolve_neighbor(
        &self,
        loc: &LogicalLocation,
        offset: [i32; 3],
    ) -> NeighborKind {
        let Some(nl) = self.neighbor_loc(loc, offset) else {
            return NeighborKind::Physical;
        };
        if self.contains(&nl) {
            return NeighborKind::SameLevel(nl);
        }
        if nl.level > 0 && self.contains(&nl.parent()) {
            return NeighborKind::Coarser(nl.parent());
        }
        // finer: children of nl adjacent to the shared boundary
        let mut fine = Vec::new();
        for c in nl.children(self.dim) {
            let bits = c.child_bits();
            let adjacent = (0..self.dim).all(|d| match offset[d] {
                // neighbor is in -d direction: we touch its + side children
                -1 => bits[d] == 1,
                1 => bits[d] == 0,
                _ => true,
            });
            if adjacent {
                if !self.contains(&c) {
                    // 2:1 violated or hole in tree — caller's bug
                    panic!(
                        "tree not properly nested at {loc:?} offset {offset:?} \
                         (missing {c:?})"
                    );
                }
                fine.push(c);
            }
        }
        NeighborKind::Finer(fine)
    }

    /// All neighbor descriptors of `loc` in canonical bufspec order.
    pub fn find_neighbors(&self, loc: &LogicalLocation) -> Vec<NeighborInfo> {
        let mut out = Vec::new();
        for (idx, off) in neighbor_offsets(self.dim).into_iter().enumerate() {
            out.push(NeighborInfo {
                offset: off,
                nbr_index: idx,
                kind: self.resolve_neighbor(loc, off),
            });
        }
        out
    }

    /// Check that the leaf set exactly tiles the domain (each finest-level
    /// root-cell covered exactly once). Used by tests/invariants.
    pub fn check_coverage(&self) -> Result<()> {
        let lmax = self.max_level();
        let mut covered: HashSet<(i64, i64, i64)> = HashSet::new();
        let mut total: u64 = 0;
        for l in &self.leaves {
            let shift = (lmax - l.level) as u32;
            let w = 1i64 << shift;
            let base = [l.lx[0] << shift, l.lx[1] << shift, l.lx[2] << shift];
            let w2 = if self.dim >= 2 { w } else { 1 };
            let w3 = if self.dim >= 3 { w } else { 1 };
            for k in 0..w3 {
                for j in 0..w2 {
                    for i in 0..w {
                        if !covered.insert((base[0] + i, base[1] + j, base[2] + k)) {
                            return Err(Error::mesh(format!(
                                "overlapping leaves at finest cell \
                                 ({},{},{})",
                                base[0] + i,
                                base[1] + j,
                                base[2] + k
                            )));
                        }
                        total += 1;
                    }
                }
            }
        }
        let mut expect: u64 = (self.nrb[0] << lmax) as u64;
        if self.dim >= 2 {
            expect *= (self.nrb[1] << lmax) as u64;
        }
        if self.dim >= 3 {
            expect *= (self.nrb[2] << lmax) as u64;
        }
        if total != expect {
            return Err(Error::mesh(format!(
                "coverage {total} != expected {expect}"
            )));
        }
        Ok(())
    }

    /// True if every pair of adjacent leaves differs by at most one level.
    pub fn is_properly_nested(&self) -> bool {
        for l in &self.leaves {
            for off in neighbor_offsets(self.dim) {
                let Some(nl) = self.neighbor_loc(l, off) else { continue };
                if self.contains(&nl) || (nl.level > 0 && self.contains(&nl.parent())) {
                    continue;
                }
                // must be exactly the adjacent children
                for c in nl.children(self.dim) {
                    let bits = c.child_bits();
                    let adjacent = (0..self.dim).all(|d| match off[d] {
                        -1 => bits[d] == 1,
                        1 => bits[d] == 0,
                        _ => true,
                    });
                    if adjacent && !self.contains(&c) {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Rebuild the tree applying per-leaf AMR flags, enforcing proper
    /// nesting and level bounds. Deterministic: every rank computes the same
    /// new tree from the same (allgathered) flags.
    pub fn regrid(&self, flags: &HashMap<LogicalLocation, AmrFlag>, max_level: u8) -> BlockTree {
        // Pass 1: apply refinement flags.
        let mut set: HashSet<LogicalLocation> = HashSet::new();
        for l in &self.leaves {
            let flag = flags.get(l).copied().unwrap_or(AmrFlag::Same);
            if flag == AmrFlag::Refine && l.level < max_level {
                for c in l.children(self.dim) {
                    set.insert(c);
                }
            } else {
                set.insert(*l);
            }
        }

        // Pass 2: enforce 2:1 nesting. Every fine leaf pushes refinement
        // onto too-coarse neighbors: for each leaf L and neighbor offset,
        // find the leaf *covering* that neighbor position (walk ancestors);
        // if it is 2+ levels coarser than L it must refine. Iterate until
        // stable (levels are small; converges in <= max_level passes).
        loop {
            let covering = |set: &HashSet<LogicalLocation>,
                            mut loc: LogicalLocation|
             -> Option<LogicalLocation> {
                loop {
                    if set.contains(&loc) {
                        return Some(loc);
                    }
                    if loc.level == 0 {
                        return None;
                    }
                    loc = loc.parent();
                }
            };
            let mut offenders: HashSet<LogicalLocation> = HashSet::new();
            for l in &set {
                for off in neighbor_offsets(self.dim) {
                    // same-level neighbor coordinates with periodic wrap
                    let mut lx = l.lx;
                    let mut outside = false;
                    for d in 0..self.dim {
                        let w = self.nrb[d] << l.level;
                        let mut v = lx[d] + off[d] as i64;
                        if v < 0 || v >= w {
                            if self.periodic[d] {
                                v = v.rem_euclid(w);
                            } else {
                                outside = true;
                                break;
                            }
                        }
                        lx[d] = v;
                    }
                    if outside {
                        continue;
                    }
                    let nl = LogicalLocation { level: l.level, lx };
                    if let Some(c) = covering(&set, nl) {
                        if c.level + 1 < l.level {
                            offenders.insert(c);
                        }
                    }
                    // if nothing covers nl it is subdivided finer than L;
                    // the finer leaves push on L when their turn comes.
                }
            }
            if offenders.is_empty() {
                break;
            }
            for l in offenders {
                if set.remove(&l) {
                    for c in l.children(self.dim) {
                        set.insert(c);
                    }
                }
            }
        }

        // Pass 3: derefinement — all siblings present, all flagged Derefine,
        // and the parent would not break nesting.
        let tmp = BlockTree::from_leaves(
            self.nrb,
            self.dim,
            self.periodic,
            set.iter().copied().collect(),
        );
        let mut groups: HashMap<LogicalLocation, Vec<LogicalLocation>> = HashMap::new();
        for l in tmp.leaves() {
            if l.level == 0 {
                continue;
            }
            groups.entry(l.parent()).or_default().push(*l);
        }
        let nchild = 1usize << self.dim;
        for (parent, kids) in groups {
            if kids.len() != nchild {
                continue;
            }
            // every child must be an original leaf flagged Derefine
            let all_flagged = kids.iter().all(|k| {
                flags.get(k).copied() == Some(AmrFlag::Derefine)
                    && self.contains(k)
            });
            if !all_flagged {
                continue;
            }
            // nesting check: no neighbor position of the parent may hold
            // leaves finer than parent.level + 1
            let ok = neighbor_offsets(self.dim).into_iter().all(|off| {
                let Some(nl) = tmp.neighbor_loc(&parent, off) else {
                    return true;
                };
                if tmp.contains(&nl) || (nl.level > 0 && tmp.contains(&nl.parent())) {
                    return true;
                }
                // children of nl adjacent to parent must all exist at
                // exactly level+1 (i.e. be leaves)
                nl.children(self.dim).iter().all(|c| {
                    let bits = c.child_bits();
                    let adjacent = (0..self.dim).all(|d| match off[d] {
                        -1 => bits[d] == 1,
                        1 => bits[d] == 0,
                        _ => true,
                    });
                    !adjacent || tmp.contains(c)
                })
            });
            if !ok {
                continue;
            }
            for k in &kids {
                set.remove(k);
            }
            set.insert(parent);
        }

        BlockTree::from_leaves(
            self.nrb,
            self.dim,
            self.periodic,
            set.into_iter().collect(),
        )
    }

    /// Refine every leaf intersecting the logical-space box (in units of the
    /// root grid, i.e. [0,1] per root block) down to `level`. Used for
    /// static mesh refinement at setup.
    pub fn refine_region(&self, lo: [f64; 3], hi: [f64; 3], level: u8) -> BlockTree {
        let mut tree = self.clone();
        for _ in 0..level {
            let mut flags = HashMap::new();
            for l in tree.leaves() {
                if l.level >= level {
                    continue;
                }
                // block extent in root-grid units
                let w = 1.0 / (1u64 << l.level) as f64;
                let mut isect = true;
                for d in 0..self.dim {
                    let b_lo = l.lx[d] as f64 * w;
                    let b_hi = b_lo + w;
                    if b_hi <= lo[d] || b_lo >= hi[d] {
                        isect = false;
                        break;
                    }
                }
                if isect {
                    flags.insert(*l, AmrFlag::Refine);
                }
            }
            if flags.is_empty() {
                break;
            }
            tree = tree.regrid(&flags, level);
        }
        tree
    }
}

/// Canonical neighbor offsets in bufspec order (must match
/// python/compile/bufspec.py): x-fastest lexicographic over (o3, o2, o1),
/// skipping (0,0,0).
pub fn neighbor_offsets(dim: usize) -> Vec<[i32; 3]> {
    let r = [-1, 0, 1];
    let r2: &[i32] = if dim >= 2 { &r } else { &[0] };
    let r3: &[i32] = if dim >= 3 { &r } else { &[0] };
    let mut out = Vec::new();
    for &o3 in r3 {
        for &o2 in r2 {
            for &o1 in &r {
                if (o1, o2, o3) != (0, 0, 0) {
                    out.push([o1, o2, o3]);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags_for(
        tree: &BlockTree,
        f: impl Fn(&LogicalLocation) -> AmrFlag,
    ) -> HashMap<LogicalLocation, AmrFlag> {
        tree.leaves().iter().map(|l| (*l, f(l))).collect()
    }

    #[test]
    fn uniform_tree_counts() {
        let t = BlockTree::uniform([4, 3, 2], 3, [true; 3]);
        assert_eq!(t.nblocks(), 24);
        assert!(t.check_coverage().is_ok());
        assert!(t.is_properly_nested());
    }

    #[test]
    fn neighbor_offsets_match_bufspec_counts() {
        assert_eq!(neighbor_offsets(1).len(), 2);
        assert_eq!(neighbor_offsets(2).len(), 8);
        assert_eq!(neighbor_offsets(3).len(), 26);
        // first 3D offset is (-1,-1,-1)? No: o3=-1,o2=-1,o1=-1 -> [-1,-1,-1]
        assert_eq!(neighbor_offsets(3)[0], [-1, -1, -1]);
        assert_eq!(neighbor_offsets(2)[0], [-1, -1, 0]);
    }

    #[test]
    fn periodic_wrap() {
        let t = BlockTree::uniform([4, 4, 1], 2, [true, true, false]);
        let l = LogicalLocation::new(0, 0, 0, 0);
        match t.resolve_neighbor(&l, [-1, 0, 0]) {
            NeighborKind::SameLevel(n) => assert_eq!(n.lx, [3, 0, 0]),
            k => panic!("expected same level, got {k:?}"),
        }
    }

    #[test]
    fn nonperiodic_physical() {
        let t = BlockTree::uniform([4, 4, 1], 2, [false, true, false]);
        let l = LogicalLocation::new(0, 0, 0, 0);
        assert_eq!(t.resolve_neighbor(&l, [-1, 0, 0]), NeighborKind::Physical);
        assert!(matches!(
            t.resolve_neighbor(&l, [0, -1, 0]),
            NeighborKind::SameLevel(_)
        ));
    }

    #[test]
    fn refine_one_block_resolves_fine_and_coarse() {
        let t = BlockTree::uniform([2, 2, 1], 2, [true, true, false]);
        let target = LogicalLocation::new(0, 0, 0, 0);
        let flags = flags_for(&t, |l| {
            if *l == target { AmrFlag::Refine } else { AmrFlag::Same }
        });
        let t2 = t.regrid(&flags, 3);
        assert_eq!(t2.nblocks(), 3 + 4);
        assert!(t2.check_coverage().is_ok());
        assert!(t2.is_properly_nested());
        // the level-0 neighbor at +x of the refined block sees two finer
        let nbr = LogicalLocation::new(0, 1, 0, 0);
        match t2.resolve_neighbor(&nbr, [-1, 0, 0]) {
            NeighborKind::Finer(f) => {
                assert_eq!(f.len(), 2);
                for c in &f {
                    assert_eq!(c.level, 1);
                    assert_eq!(c.lx[0], 1); // +x side children of (0,0)
                }
            }
            k => panic!("expected finer, got {k:?}"),
        }
        // a fine child sees the coarse neighbor
        let child = LogicalLocation::new(1, 1, 0, 0);
        match t2.resolve_neighbor(&child, [1, 0, 0]) {
            NeighborKind::Coarser(c) => assert_eq!(c, nbr),
            k => panic!("expected coarser, got {k:?}"),
        }
    }

    #[test]
    fn nesting_enforced_on_double_refine() {
        let t = BlockTree::uniform([2, 2, 1], 2, [true, true, false]);
        // refine one block twice; its neighbors must be dragged to level 1
        let target = LogicalLocation::new(0, 0, 0, 0);
        let t1 = t.regrid(
            &flags_for(&t, |l| if *l == target { AmrFlag::Refine } else { AmrFlag::Same }),
            3,
        );
        let deep = LogicalLocation::new(1, 0, 0, 0);
        let t2 = t1.regrid(
            &flags_for(&t1, |l| if *l == deep { AmrFlag::Refine } else { AmrFlag::Same }),
            3,
        );
        assert!(t2.is_properly_nested(), "2:1 must hold after regrid");
        assert!(t2.check_coverage().is_ok());
        assert!(t2.max_level() == 2);
    }

    #[test]
    fn derefine_restores_parent() {
        let t = BlockTree::uniform([2, 2, 1], 2, [true, true, false]);
        let target = LogicalLocation::new(0, 1, 1, 0);
        let t1 = t.regrid(
            &flags_for(&t, |l| if *l == target { AmrFlag::Refine } else { AmrFlag::Same }),
            3,
        );
        assert_eq!(t1.nblocks(), 7);
        let t2 = t1.regrid(&flags_for(&t1, |_| AmrFlag::Derefine), 3);
        assert_eq!(t2.nblocks(), 4);
        assert!(t2.contains(&target));
        assert!(t2.check_coverage().is_ok());
    }

    #[test]
    fn derefine_blocked_by_nesting() {
        // refine A to level 2 in a corner; its level-1 sibling group cannot
        // derefine to level 0 while level-2 leaves touch it
        let t = BlockTree::uniform([2, 2, 1], 2, [true, true, false]);
        let a = LogicalLocation::new(0, 0, 0, 0);
        let t1 = t.regrid(
            &flags_for(&t, |l| if *l == a { AmrFlag::Refine } else { AmrFlag::Same }),
            3,
        );
        let deep = LogicalLocation::new(1, 0, 0, 0);
        let t2 = t1.regrid(
            &flags_for(&t1, |l| if *l == deep { AmrFlag::Refine } else { AmrFlag::Same }),
            3,
        );
        // try to derefine everything at level 1 (the siblings of `deep`'s
        // parent group) — blocked where level-2 leaves are adjacent
        let t3 = t2.regrid(&flags_for(&t2, |_| AmrFlag::Derefine), 3);
        assert!(t3.is_properly_nested());
        assert!(t3.check_coverage().is_ok());
    }

    #[test]
    fn refine_region_smr() {
        let t = BlockTree::uniform([4, 4, 4], 3, [true; 3]);
        let t2 = t.refine_region([0.4, 0.4, 0.4], [0.6, 0.6, 0.6], 2);
        assert!(t2.max_level() == 2);
        assert!(t2.is_properly_nested());
        assert!(t2.check_coverage().is_ok());
    }

    #[test]
    fn gids_follow_morton_order() {
        let t = BlockTree::uniform([2, 2, 2], 3, [true; 3]);
        let keys: Vec<_> = t.leaves().iter().map(|l| l.morton()).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        for (i, l) in t.leaves().iter().enumerate() {
            assert_eq!(t.gid_of(l), Some(i));
        }
    }
}
