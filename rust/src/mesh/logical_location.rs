//! Logical block locations in the refinement hierarchy and Morton keys.

/// Position of a MeshBlock in the tree: refinement `level` and per-dimension
/// integer coordinates `lx` in units of blocks at that level.
///
/// At level `l` the valid range of `lx[d]` is `[0, nrb[d] << l)` where `nrb`
/// is the root-grid block count.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LogicalLocation {
    pub level: u8,
    pub lx: [i64; 3],
}

/// Maximum refinement level supported by the Morton normalization.
pub const MAX_LEVEL: u8 = 24;

impl LogicalLocation {
    pub fn new(level: u8, lx1: i64, lx2: i64, lx3: i64) -> Self {
        LogicalLocation { level, lx: [lx1, lx2, lx3] }
    }

    /// Parent location (one level coarser).
    pub fn parent(&self) -> LogicalLocation {
        debug_assert!(self.level > 0);
        LogicalLocation {
            level: self.level - 1,
            lx: [self.lx[0] >> 1, self.lx[1] >> 1, self.lx[2] >> 1],
        }
    }

    /// The `2^dim` children (one level finer), in Z-order.
    pub fn children(&self, dim: usize) -> Vec<LogicalLocation> {
        let b2: i64 = if dim >= 2 { 2 } else { 1 };
        let b3: i64 = if dim >= 3 { 2 } else { 1 };
        let mut out = Vec::with_capacity((2 * b2 * b3) as usize);
        for k in 0..b3 {
            for j in 0..b2 {
                for i in 0..2i64 {
                    out.push(LogicalLocation {
                        level: self.level + 1,
                        lx: [
                            2 * self.lx[0] + i,
                            2 * self.lx[1] + j,
                            2 * self.lx[2] + k,
                        ],
                    });
                }
            }
        }
        out
    }

    /// Which child of its parent this block is, per dimension (0 or 1).
    pub fn child_bits(&self) -> [i64; 3] {
        [self.lx[0] & 1, self.lx[1] & 1, self.lx[2] & 1]
    }

    /// True if `self` (must be finer or equal level) lies inside `other`.
    pub fn is_contained_in(&self, other: &LogicalLocation) -> bool {
        if self.level < other.level {
            return false;
        }
        let shift = self.level - other.level;
        (0..3).all(|d| (self.lx[d] >> shift) == other.lx[d])
    }

    /// Morton (Z-order) key at the finest normalization level, used to order
    /// leaves for distribution. Tie-broken by level so a parent sorts before
    /// its first child (tree-traversal order).
    pub fn morton(&self) -> (u128, u8) {
        debug_assert!(self.level <= MAX_LEVEL);
        let shift = (MAX_LEVEL - self.level) as u32;
        let f = [
            (self.lx[0] as u64) << shift,
            (self.lx[1] as u64) << shift,
            (self.lx[2] as u64) << shift,
        ];
        (interleave3(f[0], f[1], f[2]), self.level)
    }
}

/// Interleave the low 42 bits of three u64s: bit i of x lands at 3i, of y at
/// 3i+1, of z at 3i+2.
fn interleave3(x: u64, y: u64, z: u64) -> u128 {
    let mut out: u128 = 0;
    for i in 0..42 {
        out |= (((x >> i) & 1) as u128) << (3 * i);
        out |= (((y >> i) & 1) as u128) << (3 * i + 1);
        out |= (((z >> i) & 1) as u128) << (3 * i + 2);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parent_child_roundtrip() {
        let loc = LogicalLocation::new(2, 5, 3, 1);
        for c in loc.children(3) {
            assert_eq!(c.parent(), loc);
            assert!(c.is_contained_in(&loc));
        }
        assert_eq!(loc.children(3).len(), 8);
        assert_eq!(loc.children(2).len(), 4);
        assert_eq!(loc.children(1).len(), 2);
    }

    #[test]
    fn morton_orders_children_in_z_order() {
        let loc = LogicalLocation::new(0, 0, 0, 0);
        let kids = loc.children(3);
        let keys: Vec<_> = kids.iter().map(|c| c.morton()).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted, "children are generated in Z-order");
    }

    #[test]
    fn morton_parent_sorts_before_children() {
        let p = LogicalLocation::new(1, 1, 0, 0);
        for c in p.children(3) {
            assert!(p.morton() <= c.morton());
        }
        // and strictly before the first child via the level tiebreak
        assert!(p.morton() < p.children(3)[0].morton() || {
            let (k1, l1) = p.morton();
            let (k2, l2) = p.children(3)[0].morton();
            k1 == k2 && l1 < l2
        });
    }

    #[test]
    fn morton_locality() {
        // adjacent blocks at same level differ less in key than distant ones
        let a = LogicalLocation::new(3, 0, 0, 0).morton().0;
        let b = LogicalLocation::new(3, 1, 0, 0).morton().0;
        let c = LogicalLocation::new(3, 7, 7, 7).morton().0;
        assert!(b - a < c - a);
    }

    #[test]
    fn containment() {
        let root = LogicalLocation::new(0, 0, 0, 0);
        let deep = LogicalLocation::new(3, 7, 5, 2);
        assert!(deep.is_contained_in(&root));
        let other_root = LogicalLocation::new(0, 1, 0, 0);
        assert!(!deep.is_contained_in(&other_root));
        assert!(!root.is_contained_in(&deep));
    }
}
