//! The distributed Mesh: global tree + rank assignment + local MeshBlocks.
//!
//! Every rank holds the full (cheap) leaf list and the per-leaf rank
//! assignment — exactly like Parthenon/ATHENA++ — while block *data* exists
//! only on the owning rank.

use std::collections::HashMap;

use super::coords::Coords;
use super::domain::{IndexShape, RegionSize};
use super::logical_location::LogicalLocation;
use super::meshblock::MeshBlock;
use super::tree::BlockTree;
use crate::balance;
use crate::config::ParameterInput;
use crate::error::{Error, Result};
use crate::vars::{FieldDef, MeshBlockData};

/// Per-face physical boundary condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoundaryCondition {
    Periodic,
    Outflow,
    Reflect,
}

impl BoundaryCondition {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "periodic" => Ok(BoundaryCondition::Periodic),
            "outflow" => Ok(BoundaryCondition::Outflow),
            "reflecting" | "reflect" => Ok(BoundaryCondition::Reflect),
            _ => Err(Error::config(format!("unknown boundary condition {s:?}"))),
        }
    }
}

/// Static mesh configuration parsed from `<parthenon/mesh>` +
/// `<parthenon/meshblock>`.
#[derive(Debug, Clone)]
pub struct MeshConfig {
    pub dim: usize,
    /// Root grid cells per dimension.
    pub nx: [usize; 3],
    /// MeshBlock interior cells per dimension.
    pub block_nx: [usize; 3],
    /// Root grid in blocks.
    pub nrb: [i64; 3],
    pub domain: RegionSize,
    /// [dim][side: 0 = inner, 1 = outer]
    pub bcs: [[BoundaryCondition; 2]; 3],
    /// Adaptive refinement enabled.
    pub adaptive: bool,
    pub max_level: u8,
    /// Cycles between AMR checks (derefinement throttle, paper Sec. 3.8).
    pub check_interval: usize,
    /// Static refinement regions: (lo, hi in logical [0,1] units, level).
    pub static_regions: Vec<([f64; 3], [f64; 3], u8)>,
}

impl MeshConfig {
    pub fn from_params(pin: &mut ParameterInput) -> Result<Self> {
        let mb = "parthenon/meshblock";
        let m = "parthenon/mesh";
        let nx = [
            pin.int_or(m, "nx1", 64) as usize,
            pin.int_or(m, "nx2", 1) as usize,
            pin.int_or(m, "nx3", 1) as usize,
        ];
        let dim = if nx[2] > 1 { 3 } else if nx[1] > 1 { 2 } else { 1 };
        let block_nx = [
            pin.int_or(mb, "nx1", nx[0] as i64) as usize,
            pin.int_or(mb, "nx2", nx[1] as i64) as usize,
            pin.int_or(mb, "nx3", nx[2] as i64) as usize,
        ];
        let mut nrb = [1i64; 3];
        for d in 0..dim {
            if block_nx[d] == 0 || nx[d] % block_nx[d] != 0 {
                return Err(Error::mesh(format!(
                    "mesh nx{} = {} not divisible by block nx{} = {}",
                    d + 1,
                    nx[d],
                    d + 1,
                    block_nx[d]
                )));
            }
            nrb[d] = (nx[d] / block_nx[d]) as i64;
        }
        let domain = RegionSize {
            xmin: [
                pin.real_or(m, "x1min", 0.0),
                pin.real_or(m, "x2min", 0.0),
                pin.real_or(m, "x3min", 0.0),
            ],
            xmax: [
                pin.real_or(m, "x1max", 1.0),
                pin.real_or(m, "x2max", 1.0),
                pin.real_or(m, "x3max", 1.0),
            ],
        };
        let mut bcs = [[BoundaryCondition::Periodic; 2]; 3];
        for d in 0..3 {
            let keys = [format!("ix{}_bc", d + 1), format!("ox{}_bc", d + 1)];
            for (side, key) in keys.iter().enumerate() {
                let v = pin.str_or(m, key, "periodic");
                bcs[d][side] = BoundaryCondition::parse(&v)?;
            }
        }
        let refinement = pin.str_or(m, "refinement", "none");
        let adaptive = refinement == "adaptive";
        let max_level = pin.int_or(m, "numlevel", 1).max(1) as u8 - 1;
        let check_interval = pin.int_or(m, "check_refine_interval", 5) as usize;

        let mut static_regions = Vec::new();
        for idx in 0.. {
            let blk = format!("parthenon/static_refinement{idx}");
            if !pin.has(&blk, "level") {
                break;
            }
            let level = pin.int_or(&blk, "level", 1) as u8;
            let lo = [
                pin.real_or(&blk, "x1min", 0.0),
                pin.real_or(&blk, "x2min", 0.0),
                pin.real_or(&blk, "x3min", 0.0),
            ];
            let hi = [
                pin.real_or(&blk, "x1max", 1.0),
                pin.real_or(&blk, "x2max", 1.0),
                pin.real_or(&blk, "x3max", 1.0),
            ];
            // convert physical to logical [0,1] units
            let mut llo = [0.0; 3];
            let mut lhi = [1.0; 3];
            for d in 0..dim {
                llo[d] = (lo[d] - domain.xmin[d]) / domain.width(d);
                lhi[d] = (hi[d] - domain.xmin[d]) / domain.width(d);
            }
            static_regions.push((llo, lhi, level));
        }

        Ok(MeshConfig {
            dim,
            nx,
            block_nx,
            nrb,
            domain,
            bcs,
            adaptive,
            max_level,
            check_interval,
            static_regions,
        })
    }

    pub fn periodic_flags(&self) -> [bool; 3] {
        let mut p = [false; 3];
        for d in 0..self.dim {
            p[d] = self.bcs[d][0] == BoundaryCondition::Periodic
                && self.bcs[d][1] == BoundaryCondition::Periodic;
        }
        p
    }

    pub fn index_shape(&self) -> IndexShape {
        IndexShape::new(self.dim, self.block_nx)
    }

    /// Build the initial tree (uniform + static refinement regions).
    pub fn initial_tree(&self) -> BlockTree {
        let mut tree = BlockTree::uniform(self.nrb, self.dim, self.periodic_flags());
        for (lo, hi, level) in &self.static_regions {
            tree = tree.refine_region(*lo, *hi, *level);
        }
        tree
    }
}

/// The mesh as seen by one rank.
#[derive(Debug)]
pub struct Mesh {
    pub cfg: MeshConfig,
    pub tree: BlockTree,
    /// Rank owning each leaf (index = gid).
    pub ranks: Vec<usize>,
    /// Resolved field list shared by all blocks.
    pub fields: Vec<FieldDef>,
    /// Blocks owned by this rank.
    pub blocks: Vec<MeshBlock>,
    pub my_rank: usize,
    pub nranks: usize,
    /// Monotone counter bumped whenever the local block set changes
    /// (regrid, load balance, restart). Pack caches ([`crate::mesh_data`])
    /// pin the version they were built against and refuse to run stale.
    pub version: u64,
}

impl Mesh {
    /// Construct the mesh for `my_rank`, building the local blocks.
    pub fn build(
        cfg: MeshConfig,
        fields: Vec<FieldDef>,
        my_rank: usize,
        nranks: usize,
    ) -> Mesh {
        let tree = cfg.initial_tree();
        // No cycle has been measured yet, so every leaf derives the nominal
        // cost; regrid/rebalance later re-assign from the measured EWMA
        // costs (balance::derive_leaf_costs over MeshBlock::cost).
        let costs =
            balance::derive_leaf_costs(tree.leaves(), &Default::default(), cfg.dim);
        let ranks = balance::assign_blocks(&costs, nranks);
        let mut mesh = Mesh {
            cfg,
            tree,
            ranks,
            fields,
            blocks: Vec::new(),
            my_rank,
            nranks,
            version: 0,
        };
        mesh.rebuild_local_blocks();
        mesh
    }

    /// (Re)create the local MeshBlocks from tree + rank assignment. Fresh
    /// containers — callers migrate/restore data as needed. Bumps
    /// [`Mesh::version`], invalidating any pack cache built on the old
    /// block set.
    pub fn rebuild_local_blocks(&mut self) {
        self.version += 1;
        self.blocks.clear();
        let shape = self.cfg.index_shape();
        for (gid, loc) in self.tree.leaves().iter().enumerate() {
            if self.ranks[gid] != self.my_rank {
                continue;
            }
            self.blocks.push(self.make_block(gid, *loc, shape));
        }
    }

    /// Apply a new rank assignment on the SAME tree incrementally: blocks
    /// that stay on this rank keep their containers (data + cost EWMA +
    /// particle swarms) verbatim, leaving blocks are dropped (the caller
    /// has already serialized their swarms onto the migration payload),
    /// and arriving blocks get fresh containers for the caller to fill
    /// from the migration payload — including the swarms it carries.
    /// Bumps [`Mesh::version`] exactly like [`Mesh::rebuild_local_blocks`]
    /// so stale pack caches are still impossible. Returns the number of
    /// blocks whose containers survived in place.
    pub fn apply_assignment_incremental(&mut self, new_ranks: Vec<usize>) -> usize {
        assert_eq!(
            new_ranks.len(),
            self.tree.leaves().len(),
            "incremental assignment requires an unchanged tree"
        );
        self.ranks = new_ranks;
        self.version += 1;
        let shape = self.cfg.index_shape();
        let mut old: HashMap<usize, MeshBlock> = std::mem::take(&mut self.blocks)
            .into_iter()
            .map(|b| (b.gid, b))
            .collect();
        let mut blocks = Vec::new();
        let mut kept = 0usize;
        for (gid, loc) in self.tree.leaves().iter().enumerate() {
            if self.ranks[gid] != self.my_rank {
                continue;
            }
            blocks.push(match old.remove(&gid) {
                Some(b) => {
                    kept += 1;
                    b
                }
                None => self.make_block(gid, *loc, shape),
            });
        }
        self.blocks = blocks;
        kept
    }

    pub fn make_block(&self, gid: usize, loc: LogicalLocation, shape: IndexShape) -> MeshBlock {
        let coords = Coords::from_location(
            &loc,
            self.cfg.block_nx,
            self.cfg.nrb,
            &self.cfg.domain,
            self.cfg.dim,
            crate::NGHOST,
        );
        MeshBlock {
            gid,
            loc,
            coords,
            shape,
            data: MeshBlockData::from_fields(&self.fields, shape),
            swarms: HashMap::new(),
            cost: MeshBlock::DEFAULT_COST,
        }
    }

    pub fn rank_of(&self, gid: usize) -> usize {
        self.ranks[gid]
    }

    pub fn num_local_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn local_block(&self, gid: usize) -> Option<&MeshBlock> {
        self.blocks.iter().find(|b| b.gid == gid)
    }

    pub fn local_block_mut(&mut self, gid: usize) -> Option<&mut MeshBlock> {
        self.blocks.iter_mut().find(|b| b.gid == gid)
    }

    /// Interior zones across local blocks.
    pub fn local_zones(&self) -> usize {
        self.blocks.iter().map(|b| b.num_zones()).sum()
    }

    /// Map from location to (gid, rank) — used when diffing trees on regrid.
    pub fn location_map(&self) -> HashMap<LogicalLocation, (usize, usize)> {
        self.tree
            .leaves()
            .iter()
            .enumerate()
            .map(|(gid, loc)| (*loc, (gid, self.ranks[gid])))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pin_2d() -> ParameterInput {
        ParameterInput::from_str(
            r#"
<parthenon/mesh>
nx1 = 32
nx2 = 32
x1min = -0.5
x1max = 0.5
<parthenon/meshblock>
nx1 = 16
nx2 = 16
"#,
        )
        .unwrap()
    }

    #[test]
    fn config_parses() {
        let mut pin = pin_2d();
        let cfg = MeshConfig::from_params(&mut pin).unwrap();
        assert_eq!(cfg.dim, 2);
        assert_eq!(cfg.nrb, [2, 2, 1]);
        assert!((cfg.domain.width(0) - 1.0).abs() < 1e-14);
        assert_eq!(cfg.periodic_flags(), [true, true, false]);
    }

    #[test]
    fn indivisible_block_size_rejected() {
        let mut pin = pin_2d();
        pin.apply_override("parthenon/meshblock/nx1=10").unwrap();
        assert!(MeshConfig::from_params(&mut pin).is_err());
    }

    #[test]
    fn build_distributes_blocks() {
        let mut pin = pin_2d();
        let cfg = MeshConfig::from_params(&mut pin).unwrap();
        let m0 = Mesh::build(cfg.clone(), vec![], 0, 2);
        let m1 = Mesh::build(cfg, vec![], 1, 2);
        assert_eq!(m0.tree.nblocks(), 4);
        assert_eq!(m0.num_local_blocks() + m1.num_local_blocks(), 4);
        assert_eq!(m0.num_local_blocks(), 2);
        // gids are disjoint and ranks agree between the two views
        for b in &m0.blocks {
            assert_eq!(m1.rank_of(b.gid), 0);
        }
    }

    #[test]
    fn incremental_assignment_keeps_staying_blocks() {
        let mut pin = pin_2d();
        let cfg = MeshConfig::from_params(&mut pin).unwrap();
        let mut m = Mesh::build(cfg, vec![], 0, 2); // 4 blocks: rank0 = {0, 1}
        assert_eq!(m.ranks, vec![0, 0, 1, 1]);
        let v0 = m.version;
        for b in &mut m.blocks {
            b.cost = 2.0 + b.gid as f64;
        }
        // gid 1 leaves, gid 2 arrives, gid 0 stays put
        let kept = m.apply_assignment_incremental(vec![0, 1, 0, 1]);
        assert_eq!(kept, 1);
        assert!(m.version > v0, "version must bump (stale-pack safety)");
        let gids: Vec<usize> = m.blocks.iter().map(|b| b.gid).collect();
        assert_eq!(gids, vec![0, 2], "blocks stay in gid order");
        assert_eq!(m.blocks[0].cost, 2.0, "staying block keeps its cost EWMA");
        assert_eq!(
            m.blocks[1].cost,
            MeshBlock::DEFAULT_COST,
            "arriving block starts fresh until the payload fills it"
        );
    }

    #[test]
    fn static_refinement_from_input() {
        let mut pin = pin_2d();
        pin.set("parthenon/mesh", "refinement", "static");
        pin.set("parthenon/static_refinement0", "level", 1);
        pin.set("parthenon/static_refinement0", "x1min", -0.25);
        pin.set("parthenon/static_refinement0", "x1max", 0.0);
        pin.set("parthenon/static_refinement0", "x2min", 0.25);
        pin.set("parthenon/static_refinement0", "x2max", 0.5);
        let cfg = MeshConfig::from_params(&mut pin).unwrap();
        let tree = cfg.initial_tree();
        assert!(tree.max_level() == 1);
        assert!(tree.is_properly_nested());
        assert!(tree.nblocks() > 4);
    }

    #[test]
    fn boundary_condition_parsing() {
        let mut pin = pin_2d();
        pin.set("parthenon/mesh", "ix1_bc", "outflow");
        pin.set("parthenon/mesh", "ox1_bc", "reflecting");
        let cfg = MeshConfig::from_params(&mut pin).unwrap();
        assert_eq!(cfg.bcs[0][0], BoundaryCondition::Outflow);
        assert_eq!(cfg.bcs[0][1], BoundaryCondition::Reflect);
        assert_eq!(cfg.periodic_flags()[0], false);
        let tree = cfg.initial_tree();
        assert_eq!(
            tree.resolve_neighbor(
                &LogicalLocation::new(0, 0, 0, 0),
                [-1, 0, 0]
            ),
            crate::mesh::NeighborKind::Physical
        );
    }
}
