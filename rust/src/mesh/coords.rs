//! Coordinates. Uniform Cartesian only (like the paper, Sec. 7), but kept
//! behind one class so other systems can slot in.

use super::domain::RegionSize;
use super::logical_location::LogicalLocation;

/// Uniform Cartesian coordinates of one MeshBlock.
#[derive(Debug, Clone, Copy)]
pub struct Coords {
    /// Physical lower corner of the block (cell face).
    pub xmin: [f64; 3],
    /// Cell width per dimension.
    pub dx: [f64; 3],
    /// Interior cells per dimension.
    pub n: [usize; 3],
    pub dim: usize,
    ng: usize,
}

impl Coords {
    /// Coordinates of block `loc` of interior size `n` on root grid `nrb`
    /// spanning `domain`.
    pub fn from_location(
        loc: &LogicalLocation,
        n: [usize; 3],
        nrb: [i64; 3],
        domain: &RegionSize,
        dim: usize,
        ng: usize,
    ) -> Self {
        let mut xmin = [0.0; 3];
        let mut dx = [1.0; 3];
        for d in 0..3 {
            if d < dim {
                let nblocks = (nrb[d] << loc.level) as f64;
                let bw = domain.width(d) / nblocks;
                xmin[d] = domain.xmin[d] + loc.lx[d] as f64 * bw;
                dx[d] = bw / n[d] as f64;
            } else {
                xmin[d] = domain.xmin[d];
                dx[d] = domain.width(d).max(1.0);
            }
        }
        Coords { xmin, dx, n, dim, ng }
    }

    /// Cell-center coordinate along dimension d for (possibly ghost) index i
    /// of the ghosted array.
    #[inline]
    pub fn center(&self, d: usize, i: usize) -> f64 {
        let ioff = if d < self.dim { i as f64 - self.ng as f64 } else { 0.0 };
        self.xmin[d] + (ioff + 0.5) * self.dx[d]
    }

    /// Face coordinate along dimension d (face i is the lower face of cell i).
    #[inline]
    pub fn face(&self, d: usize, i: usize) -> f64 {
        let ioff = if d < self.dim { i as f64 - self.ng as f64 } else { 0.0 };
        self.xmin[d] + ioff * self.dx[d]
    }

    /// Cell volume (area in 2D, length in 1D).
    pub fn cell_volume(&self) -> f64 {
        (0..self.dim).map(|d| self.dx[d]).product()
    }

    /// Physical upper corner of the block interior.
    pub fn xmax(&self, d: usize) -> f64 {
        if d < self.dim {
            self.xmin[d] + self.dx[d] * self.n[d] as f64
        } else {
            self.xmin[d] + self.dx[d]
        }
    }

    /// True if physical point x lies inside this block's interior.
    pub fn contains(&self, x: [f64; 3]) -> bool {
        (0..self.dim).all(|d| x[d] >= self.xmin[d] && x[d] < self.xmax(d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::NGHOST;

    #[test]
    fn root_block_coords() {
        let dom = RegionSize { xmin: [-0.5, 0.0, 0.0], xmax: [0.5, 1.0, 1.0] };
        let loc = LogicalLocation::new(0, 0, 0, 0);
        let c = Coords::from_location(&loc, [16, 16, 1], [2, 2, 1], &dom, 2, NGHOST);
        assert!((c.xmin[0] - -0.5).abs() < 1e-14);
        assert!((c.dx[0] - 0.5 / 16.0).abs() < 1e-14);
        // first interior cell center
        assert!((c.center(0, NGHOST) - (-0.5 + 0.5 * c.dx[0])).abs() < 1e-14);
        // ghost cell center sits left of the block
        assert!(c.center(0, 0) < -0.5);
    }

    #[test]
    fn refined_block_is_half_size() {
        let dom = RegionSize::unit_cube();
        let coarse = Coords::from_location(
            &LogicalLocation::new(0, 0, 0, 0),
            [8, 8, 8],
            [1, 1, 1],
            &dom,
            3,
            NGHOST,
        );
        let fine = Coords::from_location(
            &LogicalLocation::new(1, 1, 0, 0),
            [8, 8, 8],
            [1, 1, 1],
            &dom,
            3,
            NGHOST,
        );
        assert!((fine.dx[0] - coarse.dx[0] / 2.0).abs() < 1e-14);
        assert!((fine.xmin[0] - 0.5).abs() < 1e-14);
    }

    #[test]
    fn containment() {
        let dom = RegionSize::unit_cube();
        let c = Coords::from_location(
            &LogicalLocation::new(1, 0, 1, 0),
            [4, 4, 1],
            [2, 2, 1],
            &dom,
            2,
            NGHOST,
        );
        assert!(c.contains([0.1, 0.3, 0.0]));
        assert!(!c.contains([0.3, 0.3, 0.0]));
    }
}
