//! The mesh: logical block locations, the (bin/quad/oct-)tree of MeshBlocks,
//! index-space conventions, coordinates, and the distributed `Mesh` object.
//!
//! Follows the block-structured AMR of ATHENA++/Parthenon (paper Sec. 2.1):
//! fixed-size MeshBlocks tile the domain, arranged in a tree; any location is
//! covered by exactly one leaf; neighbors are found by logical-coordinate
//! arithmetic; leaves are ordered by Morton (Z-order) keys for distribution;
//! the tree is rebuilt on every (de)refinement.

mod coords;
mod domain;
mod logical_location;
mod mesh_impl;
mod meshblock;
pub mod tree;

pub use coords::Coords;
pub use domain::{IndexShape, RegionSize};
pub use logical_location::LogicalLocation;
pub use mesh_impl::{BoundaryCondition, Mesh, MeshConfig};
pub use meshblock::MeshBlock;
pub use tree::{neighbor_offsets, AmrFlag, BlockTree, NeighborInfo, NeighborKind};
