//! Index-space conventions (interior vs entire domains) and the physical
//! region descriptor.

use crate::NGHOST;

/// Physical extent of the computational domain.
#[derive(Debug, Clone, Copy)]
pub struct RegionSize {
    pub xmin: [f64; 3],
    pub xmax: [f64; 3],
}

impl RegionSize {
    pub fn unit_cube() -> Self {
        RegionSize { xmin: [0.0; 3], xmax: [1.0; 3] }
    }

    pub fn width(&self, d: usize) -> f64 {
        self.xmax[d] - self.xmin[d]
    }
}

/// Per-block index shape: interior cell counts `n` (inactive dims are 1),
/// ghost width, and dimensionality. Arrays carry ghosts in active dims only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IndexShape {
    pub dim: usize,
    /// Interior cells (nx1, nx2, nx3); trailing inactive dims are 1.
    pub n: [usize; 3],
    pub ng: usize,
}

impl IndexShape {
    pub fn new(dim: usize, n: [usize; 3]) -> Self {
        debug_assert!((1..=3).contains(&dim));
        debug_assert!(n[0] >= 1);
        debug_assert!(dim < 2 || n[1] >= 1);
        debug_assert!(dim < 3 || n[2] >= 1);
        let mut n = n;
        if dim < 2 {
            n[1] = 1;
        }
        if dim < 3 {
            n[2] = 1;
        }
        IndexShape { dim, n, ng: NGHOST }
    }

    #[inline]
    pub fn active(&self, d: usize) -> bool {
        d < self.dim
    }

    /// Total cells along dimension d, ghosts included.
    #[inline]
    pub fn nt(&self, d: usize) -> usize {
        if self.active(d) {
            self.n[d] + 2 * self.ng
        } else {
            1
        }
    }

    /// First interior index along d.
    #[inline]
    pub fn is_(&self, d: usize) -> usize {
        if self.active(d) {
            self.ng
        } else {
            0
        }
    }

    /// One past the last interior index along d.
    #[inline]
    pub fn ie(&self, d: usize) -> usize {
        self.is_(d) + self.n[d]
    }

    /// Total cell count including ghosts.
    pub fn ncells_total(&self) -> usize {
        self.nt(0) * self.nt(1) * self.nt(2)
    }

    /// Interior cell count.
    pub fn ncells_interior(&self) -> usize {
        self.n[0] * self.n[1] * self.n[2]
    }

    /// Flat index of (k, j, i) in a [Z, Y, X] row-major array.
    #[inline]
    pub fn idx3(&self, k: usize, j: usize, i: usize) -> usize {
        (k * self.nt(1) + j) * self.nt(0) + i
    }

    /// Flat index of (v, k, j, i) in a [V, Z, Y, X] row-major array.
    #[inline]
    pub fn idx4(&self, v: usize, k: usize, j: usize, i: usize) -> usize {
        ((v * self.nt(2) + k) * self.nt(1) + j) * self.nt(0) + i
    }

    /// Shape as (Z, Y, X) totals — matches the artifact layout.
    pub fn total_zyx(&self) -> (usize, usize, usize) {
        (self.nt(2), self.nt(1), self.nt(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_3d() {
        let s = IndexShape::new(3, [16, 8, 4]);
        assert_eq!(s.nt(0), 20);
        assert_eq!(s.nt(1), 12);
        assert_eq!(s.nt(2), 8);
        assert_eq!(s.is_(0), 2);
        assert_eq!(s.ie(0), 18);
        assert_eq!(s.ncells_total(), 20 * 12 * 8);
        assert_eq!(s.ncells_interior(), 16 * 8 * 4);
    }

    #[test]
    fn shapes_2d_inactive_z() {
        let s = IndexShape::new(2, [16, 16, 9]);
        assert_eq!(s.n[2], 1, "inactive dim forced to 1");
        assert_eq!(s.nt(2), 1);
        assert_eq!(s.is_(2), 0);
        assert_eq!(s.ie(2), 1);
        assert_eq!(s.total_zyx(), (1, 20, 20));
    }

    #[test]
    fn idx_row_major() {
        let s = IndexShape::new(2, [4, 4, 1]);
        assert_eq!(s.idx3(0, 0, 0), 0);
        assert_eq!(s.idx3(0, 0, 1), 1);
        assert_eq!(s.idx3(0, 1, 0), 8);
        assert_eq!(s.idx4(1, 0, 0, 0), 64);
    }
}
