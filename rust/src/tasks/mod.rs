//! Task infrastructure (paper Sec. 3.10).
//!
//! Tasks are organized as `TaskCollection` → `TaskRegion` → `TaskList`:
//! regions run sequentially; the lists inside one region are polled
//! round-robin so tasks of different lists interleave ("concurrent" in the
//! paper's single-thread-per-rank sense) — this is what lets boundary
//! communication hide behind compute: a task that returns
//! [`TaskStatus::Incomplete`] (e.g. a receive that has not arrived) is
//! retried on the next sweep while other lists make progress.
//!
//! Global (cross-list) reductions are expressed as *regional* tasks: every
//! list marks a dependency task, and a single once-only task runs when all
//! marks are complete (paper's "shared dependency" reductions).

use crate::error::{Error, Result};
use crate::util::backoff::{Backoff, Deadline, ProgressWait};
use crate::util::stealing::{StealPolicy, StealPool};

/// Status returned by a task body.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskStatus {
    /// Done; dependents may run.
    Complete,
    /// Not ready (e.g. message not arrived); poll again later.
    Incomplete,
    /// Alias of Incomplete kept for Parthenon API parity (iterative tasking
    /// is driven by re-executing a region until a stop criterion holds).
    Iterate,
}

/// Handle to a task within its list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskId(usize);

/// Sentinel for "no dependencies".
pub const NONE: &[TaskId] = &[];

struct Task<C> {
    deps: Vec<TaskId>,
    body: Box<dyn FnMut(&mut C) -> TaskStatus + Send>,
    done: bool,
}

/// An ordered set of dependent tasks over one unit of work (a block or a
/// pack of blocks).
pub struct TaskList<C> {
    tasks: Vec<Task<C>>,
}

impl<C> Default for TaskList<C> {
    fn default() -> Self {
        TaskList { tasks: Vec::new() }
    }
}

impl<C> TaskList<C> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a task depending on `deps`; returns its id.
    pub fn add(
        &mut self,
        deps: &[TaskId],
        body: impl FnMut(&mut C) -> TaskStatus + Send + 'static,
    ) -> TaskId {
        let id = TaskId(self.tasks.len());
        self.tasks.push(Task { deps: deps.to_vec(), body: Box::new(body), done: false });
        id
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    fn is_done(&self, id: TaskId) -> bool {
        self.tasks[id.0].done
    }

    fn all_done(&self) -> bool {
        self.tasks.iter().all(|t| t.done)
    }

    /// Run every ready task once; returns true if anything completed.
    fn sweep(&mut self, ctx: &mut C) -> bool {
        let mut progressed = false;
        for i in 0..self.tasks.len() {
            if self.tasks[i].done {
                continue;
            }
            let ready = self.tasks[i]
                .deps
                .iter()
                .all(|d| self.tasks[d.0].done);
            if !ready {
                continue;
            }
            let status = (self.tasks[i].body)(ctx);
            if status == TaskStatus::Complete {
                self.tasks[i].done = true;
                progressed = true;
            }
        }
        progressed
    }

    /// Reset all completion state (lists are rebuilt per stage in drivers;
    /// reset supports reuse).
    pub fn reset(&mut self) {
        for t in &mut self.tasks {
            t.done = false;
        }
    }
}

/// Scheduling instrumentation for a heterogeneous region: per-list space
/// labels plus a shared counter the workers bump when a STOLEN list
/// belongs to a different space than the stealing worker's seeded items.
/// Space `255` is a wildcard (e.g. the dt-collective list) that never
/// counts as a boundary crossing.
pub struct RegionInstr<'a> {
    /// One space label per task list (0 = Host, 1 = Device, 255 = any).
    pub spaces: &'a [u8],
    /// Incremented once per cross-space steal.
    pub cross_steals: &'a std::sync::atomic::AtomicU64,
    /// One tenant (simulation) label per task list, for multi-session
    /// regions. `None` disables cross-sim attribution entirely.
    pub sims: Option<&'a [u32]>,
    /// Incremented once per steal of a list whose sim label differs from
    /// the stealing worker's home sim (the sim of its first seeded list).
    pub cross_sim_steals: Option<&'a std::sync::atomic::AtomicU64>,
}

/// A regional (cross-list) task: runs once after every (list, task) mark
/// completes. Used for task-based global reductions.
struct RegionalTask<C> {
    marks: Vec<(usize, TaskId)>,
    body: Box<dyn FnMut(&mut C) -> TaskStatus + Send>,
    done: bool,
}

/// Lists that execute concurrently (interleaved) within one region.
pub struct TaskRegion<C> {
    pub lists: Vec<TaskList<C>>,
    regional: Vec<RegionalTask<C>>,
}

impl<C> Default for TaskRegion<C> {
    fn default() -> Self {
        TaskRegion { lists: Vec::new(), regional: Vec::new() }
    }
}

impl<C> TaskRegion<C> {
    pub fn new(nlists: usize) -> Self {
        let mut r = Self::default();
        for _ in 0..nlists {
            r.lists.push(TaskList::new());
        }
        r
    }

    pub fn list(&mut self, i: usize) -> &mut TaskList<C> {
        &mut self.lists[i]
    }

    /// Add a once-only task gated on marks across lists (global reduction).
    pub fn add_regional(
        &mut self,
        marks: Vec<(usize, TaskId)>,
        body: impl FnMut(&mut C) -> TaskStatus + Send + 'static,
    ) {
        self.regional.push(RegionalTask { marks, body: Box::new(body), done: false });
    }

    /// Poll lists round-robin until every task (incl. regional) completes.
    ///
    /// `max_sweeps` bounds the number of *consecutive idle* sweeps (zero
    /// global progress — progress may depend on other ranks delivering
    /// messages). Idle sweeps wait with bounded spin-then-backoff
    /// ([`crate::util::backoff::Backoff`]) instead of pegging a core.
    pub fn execute(&mut self, ctx: &mut C, max_sweeps: usize) -> Result<()> {
        let mut backoff = crate::util::backoff::Backoff::new();
        let mut sweeps = 0usize;
        let mut idle_since: Option<std::time::Instant> = None;
        loop {
            let mut progressed = false;
            for l in &mut self.lists {
                progressed |= l.sweep(ctx);
            }
            for r in &mut self.regional {
                if r.done {
                    continue;
                }
                let ready = r
                    .marks
                    .iter()
                    .all(|(li, id)| self.lists[*li].is_done(*id));
                if ready && (r.body)(ctx) == TaskStatus::Complete {
                    r.done = true;
                    progressed = true;
                }
            }
            let all = self.lists.iter().all(|l| l.all_done())
                && self.regional.iter().all(|r| r.done);
            if all {
                return Ok(());
            }
            if !progressed {
                sweeps += 1;
                let t0 = *idle_since.get_or_insert_with(std::time::Instant::now);
                if sweeps > max_sweeps {
                    return Err(Error::Timeout {
                        what: format!(
                            "task region ({max_sweeps} idle sweeps; \
                             deadlock or lost message?)"
                        ),
                        rank: None,
                        peer: None,
                        tag: None,
                        elapsed: t0.elapsed(),
                    });
                }
                backoff.snooze();
            } else {
                sweeps = 0;
                idle_since = None;
                backoff.reset();
            }
        }
    }

    /// Execute the region's lists on a work-stealing worker pool, one
    /// independent context per list (the `Send`-splittable per-pack
    /// contexts of `bvals::exchange_tasked_parallel`).
    ///
    /// Each (list, context) pair is a pool item: a worker claims a list,
    /// sweeps it once, and — if not yet complete — re-queues it on its own
    /// deque, where idle workers can steal it. So independent task lists
    /// genuinely run concurrently, instead of being polled round-robin on
    /// one thread. Regional (cross-list) tasks stay on the coordinator
    /// (the calling thread): they run against `ctxs[0]` after every mark
    /// completes — which is guaranteed by the time the pool drains, since
    /// workers only retire fully-completed lists.
    ///
    /// Completion state is deterministic: which worker polls a list never
    /// changes what its tasks compute. Stalls are detected with the same
    /// progress-aware watchdog as the serial path.
    pub fn execute_parallel(
        &mut self,
        ctxs: Vec<C>,
        nworkers: usize,
        policy: StealPolicy,
        stall: std::time::Duration,
    ) -> Result<Vec<C>>
    where
        C: Send,
    {
        self.execute_parallel_weighted(ctxs, None, nworkers, policy, stall)
    }

    /// [`TaskRegion::execute_parallel`] with explicit per-list seed costs:
    /// the worker deques are seeded by the cost-weighted contiguous
    /// partition over `costs` instead of uniform weights. The fused stage
    /// pipeline passes its per-pack costs here so the initial deal matches
    /// the phased schedule's cost-balanced partition (stealing then closes
    /// whatever tail the communication tasks leave).
    pub fn execute_parallel_weighted(
        &mut self,
        ctxs: Vec<C>,
        costs: Option<&[f64]>,
        nworkers: usize,
        policy: StealPolicy,
        stall: std::time::Duration,
    ) -> Result<Vec<C>>
    where
        C: Send,
    {
        self.execute_parallel_weighted_instr(ctxs, costs, nworkers, policy, stall, None)
    }

    /// [`TaskRegion::execute_parallel_weighted`] with optional
    /// [`RegionInstr`] scheduling instrumentation: when present, each
    /// worker's "home" space is the space of its first seeded list, and a
    /// stolen list whose space differs bumps the shared cross-steal
    /// counter. The instrumentation observes claims only — it never
    /// changes which lists run or what they compute, so results stay
    /// bitwise identical with or without it.
    pub fn execute_parallel_weighted_instr(
        &mut self,
        ctxs: Vec<C>,
        costs: Option<&[f64]>,
        nworkers: usize,
        policy: StealPolicy,
        stall: std::time::Duration,
        instr: Option<RegionInstr<'_>>,
    ) -> Result<Vec<C>>
    where
        C: Send,
    {
        use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
        use std::sync::Mutex;

        assert_eq!(ctxs.len(), self.lists.len(), "one context per task list");
        let n = ctxs.len();
        if n == 0 {
            if !self.regional.is_empty() {
                return Err(Error::Task(
                    "regional tasks need at least one list context".into(),
                ));
            }
            return Ok(ctxs);
        }
        let lists = std::mem::take(&mut self.lists);
        let slots: Vec<Mutex<Option<(TaskList<C>, C)>>> = lists
            .into_iter()
            .zip(ctxs)
            .map(|(l, c)| Mutex::new(Some((l, c))))
            .collect();
        let pool = match costs {
            Some(c) => {
                assert_eq!(c.len(), n, "one seed cost per task list");
                StealPool::seed(c, nworkers, policy)
            }
            None => StealPool::seed(&vec![1.0; n], nworkers, policy),
        };
        let nw = pool.nworkers();
        let remaining = AtomicUsize::new(n);
        let progress = AtomicU64::new(0);
        let abort = AtomicBool::new(false);
        let instr = instr.as_ref();

        let worker = |w: usize| -> Result<()> {
            let mut backoff = Backoff::new();
            let mut watchdog = Deadline::new(stall);
            let mut seen = progress.load(Ordering::SeqCst);
            // the worker's home space = space of its first non-wildcard
            // seeded list (None when it was seeded nothing attributable)
            let my_space = instr.and_then(|ins| {
                pool.seeded(w).iter().map(|&li| ins.spaces[li]).find(|&s| s != 255)
            });
            // home tenant = sim label of the first seeded list (sim labels
            // have no wildcard: every list belongs to exactly one session)
            let my_sim = instr
                .and_then(|ins| ins.sims)
                .and_then(|sims| pool.seeded(w).first().map(|&li| sims[li]));
            // idle bookkeeping shared by the None-claim and no-progress arms
            let idle = |backoff: &mut Backoff, watchdog: &mut Deadline, seen: &mut u64| {
                let p = progress.load(Ordering::SeqCst);
                if p != *seen {
                    *seen = p;
                    backoff.reset();
                    *watchdog = Deadline::new(stall);
                    return Ok(());
                }
                if watchdog.expired() {
                    abort.store(true, Ordering::SeqCst);
                    return Err(Error::Timeout {
                        what: format!(
                            "parallel task region ({} lists incomplete)",
                            remaining.load(Ordering::SeqCst)
                        ),
                        rank: None,
                        peer: None,
                        tag: None,
                        elapsed: watchdog.elapsed(),
                    });
                }
                backoff.snooze();
                Ok(())
            };
            loop {
                if remaining.load(Ordering::SeqCst) == 0 || abort.load(Ordering::SeqCst) {
                    return Ok(());
                }
                let Some((li, stolen)) = pool.claim2(w) else {
                    // every incomplete list is momentarily held by another worker
                    idle(&mut backoff, &mut watchdog, &mut seen)?;
                    continue;
                };
                if stolen {
                    if let (Some(ins), Some(ms)) = (instr, my_space) {
                        let s = ins.spaces[li];
                        if s != 255 && s != ms {
                            ins.cross_steals.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    if let (Some(ins), Some(msim)) = (instr, my_sim) {
                        if let (Some(sims), Some(ctr)) =
                            (ins.sims, ins.cross_sim_steals)
                        {
                            if sims[li] != msim {
                                ctr.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                    }
                }
                let taken = slots[li].lock().unwrap().take();
                let Some((mut list, mut ctx)) = taken else { continue };
                let progressed = list.sweep(&mut ctx);
                let finished = list.all_done();
                *slots[li].lock().unwrap() = Some((list, ctx));
                if finished {
                    remaining.fetch_sub(1, Ordering::SeqCst);
                    progress.fetch_add(1, Ordering::SeqCst);
                    backoff.reset();
                    watchdog = Deadline::new(stall);
                } else {
                    // restore-then-requeue: the slot is always populated
                    // before the index becomes claimable again
                    pool.push(w, li);
                    if progressed {
                        progress.fetch_add(1, Ordering::SeqCst);
                        backoff.reset();
                        watchdog = Deadline::new(stall);
                    } else {
                        idle(&mut backoff, &mut watchdog, &mut seen)?;
                    }
                }
            }
        };

        let results: Vec<Result<()>> = if nw <= 1 {
            vec![worker(0)]
        } else {
            let worker = &worker;
            std::thread::scope(|s| {
                let handles: Vec<_> =
                    (0..nw).map(|w| s.spawn(move || worker(w))).collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("task-region worker panicked"))
                    .collect()
            })
        };

        // restore lists + recover contexts (also on error paths)
        let mut out = Vec::with_capacity(n);
        self.lists = slots
            .into_iter()
            .map(|m| {
                let (l, c) = m
                    .into_inner()
                    .unwrap()
                    .expect("every slot is restored after its sweep");
                out.push(c);
                l
            })
            .collect();
        for r in results {
            r?;
        }

        // regional tasks on the coordinator: all marks are complete here
        if !self.regional.is_empty() {
            let ctx = &mut out[0];
            let mut wait = ProgressWait::new(stall);
            loop {
                let mut progressed = false;
                let mut all_done = true;
                for r in &mut self.regional {
                    if r.done {
                        continue;
                    }
                    let ready =
                        r.marks.iter().all(|(li, id)| self.lists[*li].is_done(*id));
                    if ready && (r.body)(ctx) == TaskStatus::Complete {
                        r.done = true;
                        progressed = true;
                    }
                    if !r.done {
                        all_done = false;
                    }
                }
                if all_done {
                    break;
                }
                if !wait.step(progressed) {
                    return Err(Error::Timeout {
                        what: "regional tasks after parallel region".into(),
                        rank: None,
                        peer: None,
                        tag: None,
                        elapsed: wait.idle_elapsed(),
                    });
                }
            }
        }
        Ok(out)
    }
}

/// Regions executed in order — one per algorithm phase (paper Fig. 3).
pub struct TaskCollection<C> {
    pub regions: Vec<TaskRegion<C>>,
}

impl<C> Default for TaskCollection<C> {
    fn default() -> Self {
        TaskCollection { regions: Vec::new() }
    }
}

impl<C> TaskCollection<C> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_region(&mut self, nlists: usize) -> &mut TaskRegion<C> {
        self.regions.push(TaskRegion::new(nlists));
        self.regions.last_mut().unwrap()
    }

    pub fn execute(&mut self, ctx: &mut C, max_sweeps: usize) -> Result<()> {
        for r in &mut self.regions {
            r.execute(ctx, max_sweeps)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct Ctx {
        log: Vec<&'static str>,
        counter: usize,
    }

    #[test]
    fn dependencies_order_execution() {
        let mut list = TaskList::<Ctx>::new();
        let a = list.add(NONE, |c: &mut Ctx| {
            c.log.push("a");
            TaskStatus::Complete
        });
        let b = list.add(&[a], |c: &mut Ctx| {
            c.log.push("b");
            TaskStatus::Complete
        });
        let _c = list.add(&[a, b], |c: &mut Ctx| {
            c.log.push("c");
            TaskStatus::Complete
        });
        let mut region = TaskRegion { lists: vec![list], regional: vec![] };
        let mut ctx = Ctx::default();
        region.execute(&mut ctx, 10).unwrap();
        assert_eq!(ctx.log, vec!["a", "b", "c"]);
    }

    #[test]
    fn incomplete_retries_until_ready() {
        let mut list = TaskList::<Ctx>::new();
        list.add(NONE, |c: &mut Ctx| {
            c.counter += 1;
            if c.counter >= 3 {
                TaskStatus::Complete
            } else {
                TaskStatus::Incomplete
            }
        });
        let mut region = TaskRegion { lists: vec![list], regional: vec![] };
        let mut ctx = Ctx::default();
        region.execute(&mut ctx, 100).unwrap();
        assert_eq!(ctx.counter, 3);
    }

    #[test]
    fn lists_interleave() {
        // list 0 waits for a flag only list 1 sets -> requires interleaving
        let mut region = TaskRegion::<Ctx>::new(2);
        region.list(0).add(NONE, |c: &mut Ctx| {
            if c.counter > 0 {
                c.log.push("waiter");
                TaskStatus::Complete
            } else {
                TaskStatus::Incomplete
            }
        });
        region.list(1).add(NONE, |c: &mut Ctx| {
            c.counter = 1;
            c.log.push("setter");
            TaskStatus::Complete
        });
        let mut ctx = Ctx::default();
        region.execute(&mut ctx, 10).unwrap();
        assert_eq!(ctx.log, vec!["setter", "waiter"]);
    }

    #[test]
    fn regional_runs_once_after_marks() {
        let mut region = TaskRegion::<Ctx>::new(2);
        let mut marks = Vec::new();
        for li in 0..2 {
            let id = region.list(li).add(NONE, |c: &mut Ctx| {
                c.counter += 1;
                TaskStatus::Complete
            });
            marks.push((li, id));
        }
        region.add_regional(marks, |c: &mut Ctx| {
            c.log.push("reduce");
            assert_eq!(c.counter, 2, "runs after all marks");
            TaskStatus::Complete
        });
        let mut ctx = Ctx::default();
        region.execute(&mut ctx, 10).unwrap();
        assert_eq!(ctx.log, vec!["reduce"]);
    }

    #[test]
    fn stall_detected() {
        let mut region = TaskRegion::<Ctx>::new(1);
        region.list(0).add(NONE, |_: &mut Ctx| TaskStatus::Incomplete);
        let mut ctx = Ctx::default();
        assert!(region.execute(&mut ctx, 5).is_err());
    }

    #[test]
    fn collection_runs_regions_in_order() {
        let mut coll = TaskCollection::<Ctx>::new();
        coll.add_region(1).list(0).add(NONE, |c: &mut Ctx| {
            c.log.push("r0");
            TaskStatus::Complete
        });
        coll.add_region(1).list(0).add(NONE, |c: &mut Ctx| {
            c.log.push("r1");
            TaskStatus::Complete
        });
        let mut ctx = Ctx::default();
        coll.execute(&mut ctx, 10).unwrap();
        assert_eq!(ctx.log, vec!["r0", "r1"]);
    }

    #[test]
    fn parallel_lists_complete_under_every_policy() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        use std::time::Duration;
        for policy in [
            StealPolicy::NoSteal,
            StealPolicy::Heaviest,
            StealPolicy::RoundRobin,
            StealPolicy::Reverse,
        ] {
            for nworkers in [1usize, 2, 4] {
                let n = 6;
                let shared = Arc::new(AtomicUsize::new(0));
                let mut region: TaskRegion<Arc<AtomicUsize>> = TaskRegion::new(n);
                for li in 0..n {
                    region.list(li).add(NONE, |c: &mut Arc<AtomicUsize>| {
                        c.fetch_add(1, Ordering::SeqCst);
                        TaskStatus::Complete
                    });
                }
                let ctxs: Vec<_> = (0..n).map(|_| shared.clone()).collect();
                region
                    .execute_parallel(ctxs, nworkers, policy, Duration::from_secs(30))
                    .unwrap();
                assert_eq!(
                    shared.load(Ordering::SeqCst),
                    n,
                    "policy {policy:?} nworkers {nworkers}"
                );
            }
        }
    }

    #[test]
    fn parallel_lists_interleave_via_requeue() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        use std::time::Duration;
        for nworkers in [1usize, 2] {
            let shared = Arc::new(AtomicUsize::new(0));
            let mut region: TaskRegion<Arc<AtomicUsize>> = TaskRegion::new(2);
            // list 0 polls until list 1 sets the flag — requires the
            // incomplete list to be re-queued, not spun to completion
            region.list(0).add(NONE, |c: &mut Arc<AtomicUsize>| {
                if c.load(Ordering::SeqCst) > 0 {
                    TaskStatus::Complete
                } else {
                    TaskStatus::Incomplete
                }
            });
            region.list(1).add(NONE, |c: &mut Arc<AtomicUsize>| {
                c.store(1, Ordering::SeqCst);
                TaskStatus::Complete
            });
            let ctxs = vec![shared.clone(), shared.clone()];
            region
                .execute_parallel(
                    ctxs,
                    nworkers,
                    StealPolicy::Heaviest,
                    Duration::from_secs(30),
                )
                .unwrap();
        }
    }

    #[test]
    fn parallel_stall_detected() {
        use std::time::Duration;
        let mut region: TaskRegion<Ctx> = TaskRegion::new(1);
        region.list(0).add(NONE, |_: &mut Ctx| TaskStatus::Incomplete);
        let err = region.execute_parallel(
            vec![Ctx::default()],
            2,
            StealPolicy::Heaviest,
            Duration::from_millis(50),
        );
        assert!(err.is_err(), "never-completing list must stall out");
    }

    #[test]
    fn parallel_regional_runs_after_lists() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        use std::time::Duration;
        let shared = Arc::new(AtomicUsize::new(0));
        let mut region: TaskRegion<Arc<AtomicUsize>> = TaskRegion::new(2);
        let mut marks = Vec::new();
        for li in 0..2 {
            let id = region.list(li).add(NONE, |c: &mut Arc<AtomicUsize>| {
                c.fetch_add(1, Ordering::SeqCst);
                TaskStatus::Complete
            });
            marks.push((li, id));
        }
        region.add_regional(marks, |c: &mut Arc<AtomicUsize>| {
            assert_eq!(c.load(Ordering::SeqCst), 2, "after all marks");
            c.fetch_add(10, Ordering::SeqCst);
            TaskStatus::Complete
        });
        let ctxs = vec![shared.clone(), shared.clone()];
        region
            .execute_parallel(ctxs, 2, StealPolicy::Heaviest, Duration::from_secs(30))
            .unwrap();
        assert_eq!(shared.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn parallel_fused_shape_overlaps_comm_with_compute() {
        // Model of the fused stage pipeline: every list runs compute ->
        // send -> poll, where list i's poll only completes after list
        // (i+1)'s send (cyclic). Finishing requires incomplete polls to
        // yield their worker back while other lists' compute/send tasks
        // run — communication hiding behind compute within one region.
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        use std::time::Duration;
        type FCtx = (usize, Arc<Vec<AtomicUsize>>);
        let n = 4usize;
        for nworkers in [1usize, 2, 4] {
            let sent: Arc<Vec<AtomicUsize>> =
                Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
            let mut region: TaskRegion<FCtx> = TaskRegion::new(n);
            for li in 0..n {
                let list = region.list(li);
                let t_compute = list.add(NONE, |_: &mut FCtx| TaskStatus::Complete);
                let t_send = list.add(&[t_compute], |c: &mut FCtx| {
                    c.1[c.0].store(1, Ordering::SeqCst);
                    TaskStatus::Complete
                });
                let _t_poll = list.add(&[t_send], |c: &mut FCtx| {
                    let src = (c.0 + 1) % c.1.len();
                    if c.1[src].load(Ordering::SeqCst) > 0 {
                        TaskStatus::Complete
                    } else {
                        TaskStatus::Incomplete
                    }
                });
            }
            let ctxs: Vec<FCtx> = (0..n).map(|i| (i, sent.clone())).collect();
            let costs: Vec<f64> = (0..n).map(|i| (i + 1) as f64).collect();
            region
                .execute_parallel_weighted(
                    ctxs,
                    Some(&costs),
                    nworkers,
                    StealPolicy::Heaviest,
                    Duration::from_secs(30),
                )
                .unwrap();
            assert!(sent.iter().all(|s| s.load(Ordering::SeqCst) == 1));
        }
    }

    #[test]
    fn region_instr_counts_cross_space_steals_only() {
        use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
        use std::sync::Arc;
        use std::time::Duration;
        // The cost skew seeds worker 0 with ONLY the heavy list 0 and
        // worker 1 with lists 1..8; worker 0 finishes first and steals
        // from worker 1. With list 0 in space 0 and the rest in space 1,
        // every such steal crosses the boundary; with uniform labels the
        // same steals must count nothing.
        for (spaces, expect_cross) in [
            (
                vec![0u8, 1, 1, 1, 1, 1, 1, 1],
                true,
            ),
            (vec![0u8; 8], false),
        ] {
            let cross = AtomicU64::new(0);
            let done = Arc::new(AtomicUsize::new(0));
            let mut region: TaskRegion<Arc<AtomicUsize>> = TaskRegion::new(8);
            for li in 0..8 {
                region.list(li).add(NONE, |c: &mut Arc<AtomicUsize>| {
                    std::thread::sleep(Duration::from_millis(2));
                    c.fetch_add(1, Ordering::SeqCst);
                    TaskStatus::Complete
                });
            }
            let ctxs: Vec<_> = (0..8).map(|_| done.clone()).collect();
            // cost skew: list 0 dominates, so worker 0's seed is just it
            let costs = vec![1000.0, 0.001, 0.001, 0.001, 0.001, 0.001, 0.001, 0.001];
            region
                .execute_parallel_weighted_instr(
                    ctxs,
                    Some(&costs),
                    2,
                    StealPolicy::Heaviest,
                    Duration::from_secs(30),
                    Some(RegionInstr {
                        spaces: &spaces,
                        cross_steals: &cross,
                        sims: None,
                        cross_sim_steals: None,
                    }),
                )
                .unwrap();
            assert_eq!(done.load(Ordering::SeqCst), 8);
            if expect_cross {
                assert!(
                    cross.load(Ordering::SeqCst) > 0,
                    "skewed seed must produce a cross-space steal"
                );
            } else {
                assert_eq!(
                    cross.load(Ordering::SeqCst),
                    0,
                    "uniform-space region must count no cross steals"
                );
            }
        }
    }

    #[test]
    fn random_dags_respect_deps() {
        use crate::util::rng::XorShift;
        use crate::util::testutil::check;
        use std::sync::{Arc, Mutex};

        check("task dag", 20, |rng: &mut XorShift| {
            let n = 2 + rng.below(20);
            let mut list = TaskList::<Ctx>::new();
            let order: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
            let mut ids: Vec<TaskId> = Vec::new();
            let mut deps_of: Vec<Vec<usize>> = Vec::new();
            for i in 0..n {
                let ndeps = rng.below(i.min(3) + 1);
                let mut deps = Vec::new();
                for _ in 0..ndeps {
                    deps.push(rng.below(i.max(1)));
                }
                deps.dedup();
                let dep_ids: Vec<TaskId> = deps.iter().map(|&d| ids[d]).collect();
                let ord = order.clone();
                ids.push(list.add(&dep_ids, move |_: &mut Ctx| {
                    ord.lock().unwrap().push(i);
                    TaskStatus::Complete
                }));
                deps_of.push(deps);
            }
            let mut region = TaskRegion { lists: vec![list], regional: vec![] };
            region.execute(&mut Ctx::default(), 10).unwrap();
            let seq = order.lock().unwrap();
            let pos: std::collections::HashMap<usize, usize> =
                seq.iter().enumerate().map(|(p, &t)| (t, p)).collect();
            for (i, deps) in deps_of.iter().enumerate() {
                for &d in deps {
                    assert!(pos[&d] < pos[&i], "dep {d} must precede {i}");
                }
            }
        });
    }
}
