//! Task infrastructure (paper Sec. 3.10).
//!
//! Tasks are organized as `TaskCollection` → `TaskRegion` → `TaskList`:
//! regions run sequentially; the lists inside one region are polled
//! round-robin so tasks of different lists interleave ("concurrent" in the
//! paper's single-thread-per-rank sense) — this is what lets boundary
//! communication hide behind compute: a task that returns
//! [`TaskStatus::Incomplete`] (e.g. a receive that has not arrived) is
//! retried on the next sweep while other lists make progress.
//!
//! Global (cross-list) reductions are expressed as *regional* tasks: every
//! list marks a dependency task, and a single once-only task runs when all
//! marks are complete (paper's "shared dependency" reductions).

use crate::error::{Error, Result};

/// Status returned by a task body.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskStatus {
    /// Done; dependents may run.
    Complete,
    /// Not ready (e.g. message not arrived); poll again later.
    Incomplete,
    /// Alias of Incomplete kept for Parthenon API parity (iterative tasking
    /// is driven by re-executing a region until a stop criterion holds).
    Iterate,
}

/// Handle to a task within its list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskId(usize);

/// Sentinel for "no dependencies".
pub const NONE: &[TaskId] = &[];

struct Task<C> {
    deps: Vec<TaskId>,
    body: Box<dyn FnMut(&mut C) -> TaskStatus + Send>,
    done: bool,
}

/// An ordered set of dependent tasks over one unit of work (a block or a
/// pack of blocks).
pub struct TaskList<C> {
    tasks: Vec<Task<C>>,
}

impl<C> Default for TaskList<C> {
    fn default() -> Self {
        TaskList { tasks: Vec::new() }
    }
}

impl<C> TaskList<C> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a task depending on `deps`; returns its id.
    pub fn add(
        &mut self,
        deps: &[TaskId],
        body: impl FnMut(&mut C) -> TaskStatus + Send + 'static,
    ) -> TaskId {
        let id = TaskId(self.tasks.len());
        self.tasks.push(Task { deps: deps.to_vec(), body: Box::new(body), done: false });
        id
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    fn is_done(&self, id: TaskId) -> bool {
        self.tasks[id.0].done
    }

    fn all_done(&self) -> bool {
        self.tasks.iter().all(|t| t.done)
    }

    /// Run every ready task once; returns true if anything completed.
    fn sweep(&mut self, ctx: &mut C) -> bool {
        let mut progressed = false;
        for i in 0..self.tasks.len() {
            if self.tasks[i].done {
                continue;
            }
            let ready = self.tasks[i]
                .deps
                .iter()
                .all(|d| self.tasks[d.0].done);
            if !ready {
                continue;
            }
            let status = (self.tasks[i].body)(ctx);
            if status == TaskStatus::Complete {
                self.tasks[i].done = true;
                progressed = true;
            }
        }
        progressed
    }

    /// Reset all completion state (lists are rebuilt per stage in drivers;
    /// reset supports reuse).
    pub fn reset(&mut self) {
        for t in &mut self.tasks {
            t.done = false;
        }
    }
}

/// A regional (cross-list) task: runs once after every (list, task) mark
/// completes. Used for task-based global reductions.
struct RegionalTask<C> {
    marks: Vec<(usize, TaskId)>,
    body: Box<dyn FnMut(&mut C) -> TaskStatus + Send>,
    done: bool,
}

/// Lists that execute concurrently (interleaved) within one region.
pub struct TaskRegion<C> {
    pub lists: Vec<TaskList<C>>,
    regional: Vec<RegionalTask<C>>,
}

impl<C> Default for TaskRegion<C> {
    fn default() -> Self {
        TaskRegion { lists: Vec::new(), regional: Vec::new() }
    }
}

impl<C> TaskRegion<C> {
    pub fn new(nlists: usize) -> Self {
        let mut r = Self::default();
        for _ in 0..nlists {
            r.lists.push(TaskList::new());
        }
        r
    }

    pub fn list(&mut self, i: usize) -> &mut TaskList<C> {
        &mut self.lists[i]
    }

    /// Add a once-only task gated on marks across lists (global reduction).
    pub fn add_regional(
        &mut self,
        marks: Vec<(usize, TaskId)>,
        body: impl FnMut(&mut C) -> TaskStatus + Send + 'static,
    ) {
        self.regional.push(RegionalTask { marks, body: Box::new(body), done: false });
    }

    /// Poll lists round-robin until every task (incl. regional) completes.
    ///
    /// `max_sweeps` bounds the number of *consecutive idle* sweeps (zero
    /// global progress — progress may depend on other ranks delivering
    /// messages). Idle sweeps wait with bounded spin-then-backoff
    /// ([`crate::util::backoff::Backoff`]) instead of pegging a core.
    pub fn execute(&mut self, ctx: &mut C, max_sweeps: usize) -> Result<()> {
        let mut backoff = crate::util::backoff::Backoff::new();
        let mut sweeps = 0usize;
        loop {
            let mut progressed = false;
            for l in &mut self.lists {
                progressed |= l.sweep(ctx);
            }
            for r in &mut self.regional {
                if r.done {
                    continue;
                }
                let ready = r
                    .marks
                    .iter()
                    .all(|(li, id)| self.lists[*li].is_done(*id));
                if ready && (r.body)(ctx) == TaskStatus::Complete {
                    r.done = true;
                    progressed = true;
                }
            }
            let all = self.lists.iter().all(|l| l.all_done())
                && self.regional.iter().all(|r| r.done);
            if all {
                return Ok(());
            }
            if !progressed {
                sweeps += 1;
                if sweeps > max_sweeps {
                    return Err(Error::Task(format!(
                        "region stalled after {max_sweeps} idle sweeps \
                         (deadlock or lost message?)"
                    )));
                }
                backoff.snooze();
            } else {
                sweeps = 0;
                backoff.reset();
            }
        }
    }
}

/// Regions executed in order — one per algorithm phase (paper Fig. 3).
pub struct TaskCollection<C> {
    pub regions: Vec<TaskRegion<C>>,
}

impl<C> Default for TaskCollection<C> {
    fn default() -> Self {
        TaskCollection { regions: Vec::new() }
    }
}

impl<C> TaskCollection<C> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_region(&mut self, nlists: usize) -> &mut TaskRegion<C> {
        self.regions.push(TaskRegion::new(nlists));
        self.regions.last_mut().unwrap()
    }

    pub fn execute(&mut self, ctx: &mut C, max_sweeps: usize) -> Result<()> {
        for r in &mut self.regions {
            r.execute(ctx, max_sweeps)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct Ctx {
        log: Vec<&'static str>,
        counter: usize,
    }

    #[test]
    fn dependencies_order_execution() {
        let mut list = TaskList::<Ctx>::new();
        let a = list.add(NONE, |c: &mut Ctx| {
            c.log.push("a");
            TaskStatus::Complete
        });
        let b = list.add(&[a], |c: &mut Ctx| {
            c.log.push("b");
            TaskStatus::Complete
        });
        let _c = list.add(&[a, b], |c: &mut Ctx| {
            c.log.push("c");
            TaskStatus::Complete
        });
        let mut region = TaskRegion { lists: vec![list], regional: vec![] };
        let mut ctx = Ctx::default();
        region.execute(&mut ctx, 10).unwrap();
        assert_eq!(ctx.log, vec!["a", "b", "c"]);
    }

    #[test]
    fn incomplete_retries_until_ready() {
        let mut list = TaskList::<Ctx>::new();
        list.add(NONE, |c: &mut Ctx| {
            c.counter += 1;
            if c.counter >= 3 {
                TaskStatus::Complete
            } else {
                TaskStatus::Incomplete
            }
        });
        let mut region = TaskRegion { lists: vec![list], regional: vec![] };
        let mut ctx = Ctx::default();
        region.execute(&mut ctx, 100).unwrap();
        assert_eq!(ctx.counter, 3);
    }

    #[test]
    fn lists_interleave() {
        // list 0 waits for a flag only list 1 sets -> requires interleaving
        let mut region = TaskRegion::<Ctx>::new(2);
        region.list(0).add(NONE, |c: &mut Ctx| {
            if c.counter > 0 {
                c.log.push("waiter");
                TaskStatus::Complete
            } else {
                TaskStatus::Incomplete
            }
        });
        region.list(1).add(NONE, |c: &mut Ctx| {
            c.counter = 1;
            c.log.push("setter");
            TaskStatus::Complete
        });
        let mut ctx = Ctx::default();
        region.execute(&mut ctx, 10).unwrap();
        assert_eq!(ctx.log, vec!["setter", "waiter"]);
    }

    #[test]
    fn regional_runs_once_after_marks() {
        let mut region = TaskRegion::<Ctx>::new(2);
        let mut marks = Vec::new();
        for li in 0..2 {
            let id = region.list(li).add(NONE, |c: &mut Ctx| {
                c.counter += 1;
                TaskStatus::Complete
            });
            marks.push((li, id));
        }
        region.add_regional(marks, |c: &mut Ctx| {
            c.log.push("reduce");
            assert_eq!(c.counter, 2, "runs after all marks");
            TaskStatus::Complete
        });
        let mut ctx = Ctx::default();
        region.execute(&mut ctx, 10).unwrap();
        assert_eq!(ctx.log, vec!["reduce"]);
    }

    #[test]
    fn stall_detected() {
        let mut region = TaskRegion::<Ctx>::new(1);
        region.list(0).add(NONE, |_: &mut Ctx| TaskStatus::Incomplete);
        let mut ctx = Ctx::default();
        assert!(region.execute(&mut ctx, 5).is_err());
    }

    #[test]
    fn collection_runs_regions_in_order() {
        let mut coll = TaskCollection::<Ctx>::new();
        coll.add_region(1).list(0).add(NONE, |c: &mut Ctx| {
            c.log.push("r0");
            TaskStatus::Complete
        });
        coll.add_region(1).list(0).add(NONE, |c: &mut Ctx| {
            c.log.push("r1");
            TaskStatus::Complete
        });
        let mut ctx = Ctx::default();
        coll.execute(&mut ctx, 10).unwrap();
        assert_eq!(ctx.log, vec!["r0", "r1"]);
    }

    #[test]
    fn random_dags_respect_deps() {
        use crate::util::rng::XorShift;
        use crate::util::testutil::check;
        use std::sync::{Arc, Mutex};

        check("task dag", 20, |rng: &mut XorShift| {
            let n = 2 + rng.below(20);
            let mut list = TaskList::<Ctx>::new();
            let order: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
            let mut ids: Vec<TaskId> = Vec::new();
            let mut deps_of: Vec<Vec<usize>> = Vec::new();
            for i in 0..n {
                let ndeps = rng.below(i.min(3) + 1);
                let mut deps = Vec::new();
                for _ in 0..ndeps {
                    deps.push(rng.below(i.max(1)));
                }
                deps.dedup();
                let dep_ids: Vec<TaskId> = deps.iter().map(|&d| ids[d]).collect();
                let ord = order.clone();
                ids.push(list.add(&dep_ids, move |_: &mut Ctx| {
                    ord.lock().unwrap().push(i);
                    TaskStatus::Complete
                }));
                deps_of.push(deps);
            }
            let mut region = TaskRegion { lists: vec![list], regional: vec![] };
            region.execute(&mut Ctx::default(), 10).unwrap();
            let seq = order.lock().unwrap();
            let pos: std::collections::HashMap<usize, usize> =
                seq.iter().enumerate().map(|(p, &t)| (t, p)).collect();
            for (i, deps) in deps_of.iter().enumerate() {
                for &d in deps {
                    assert!(pos[&d] < pos[&i], "dep {d} must precede {i}");
                }
            }
        });
    }
}
