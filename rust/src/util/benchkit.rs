//! Hand-rolled benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timed runs with median/MAD statistics, a
//! paper-style table printer, and JSON result dumps under `bench_results/`.
//! Every `cargo bench` target builds its harness from these pieces.

use std::time::{Duration, Instant};

use super::json::{obj, Json};

/// One measured configuration.
#[derive(Debug, Clone)]
pub struct Sample {
    pub label: String,
    /// Wall-clock per repetition, seconds.
    pub secs: Vec<f64>,
    /// Work units per repetition (e.g. zone-updates), for throughput.
    pub work: f64,
}

impl Sample {
    pub fn median_secs(&self) -> f64 {
        let mut s = self.secs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }

    /// Median absolute deviation (robust spread).
    pub fn mad_secs(&self) -> f64 {
        let m = self.median_secs();
        let mut d: Vec<f64> = self.secs.iter().map(|s| (s - m).abs()).collect();
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = d.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 {
            d[n / 2]
        } else {
            0.5 * (d[n / 2 - 1] + d[n / 2])
        }
    }

    /// Work units per second (throughput) at the median.
    pub fn throughput(&self) -> f64 {
        self.work / self.median_secs()
    }
}

/// Time `f` with `warmup` untimed + `reps` timed repetitions.
pub fn run<F: FnMut()>(label: &str, work: f64, warmup: usize, reps: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut secs = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        secs.push(t0.elapsed().as_secs_f64());
    }
    Sample {
        label: label.to_string(),
        secs,
        work,
    }
}

/// Time a closure that reports its own work units (e.g. cycles actually run).
pub fn run_with_work<F: FnMut() -> f64>(
    label: &str,
    warmup: usize,
    reps: usize,
    mut f: F,
) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut secs = Vec::with_capacity(reps);
    let mut work = 0.0;
    for _ in 0..reps {
        let t0 = Instant::now();
        work = f();
        secs.push(t0.elapsed().as_secs_f64());
    }
    Sample {
        label: label.to_string(),
        secs,
        work,
    }
}

/// True when PARTHENON_BENCH_QUICK=1: shrink workloads for CI runs.
pub fn quick_mode() -> bool {
    std::env::var("PARTHENON_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Simple fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>width$} | ", c, width = w[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        println!(
            "|{}|",
            w.iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

/// Write bench samples to bench_results/<name>.json.
pub fn write_results(name: &str, samples: &[Sample], extra: Vec<(&str, Json)>) {
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let mut items = Vec::new();
    for s in samples {
        items.push(obj(vec![
            ("label", s.label.as_str().into()),
            ("median_secs", s.median_secs().into()),
            ("mad_secs", s.mad_secs().into()),
            ("work", s.work.into()),
            ("throughput", s.throughput().into()),
            ("reps", s.secs.len().into()),
        ]));
    }
    let mut fields = vec![
        ("name", Json::from(name)),
        ("samples", Json::Arr(items)),
    ];
    fields.extend(extra);
    let doc = obj(fields);
    let path = dir.join(format!("{name}.json"));
    if let Err(e) = std::fs::write(&path, doc.dump()) {
        eprintln!("benchkit: failed to write {path:?}: {e}");
    } else {
        println!("[benchkit] wrote {path:?}");
    }
}

/// Format zone-cycles/s compactly (3 significant figures).
pub fn fmt_zcps(zcps: f64) -> String {
    format!("{zcps:.3e}")
}

/// Busy-sleep helper for calibration tests.
pub fn spin_for(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        let s = Sample { label: "x".into(), secs: vec![3.0, 1.0, 2.0], work: 6.0 };
        assert_eq!(s.median_secs(), 2.0);
        let s2 = Sample { label: "x".into(), secs: vec![1.0, 2.0, 3.0, 4.0], work: 1.0 };
        assert_eq!(s2.median_secs(), 2.5);
    }

    #[test]
    fn throughput_uses_median() {
        let s = Sample { label: "x".into(), secs: vec![2.0, 2.0, 2.0], work: 10.0 };
        assert_eq!(s.throughput(), 5.0);
    }

    #[test]
    fn run_measures() {
        let s = run("spin", 1.0, 1, 3, || spin_for(Duration::from_millis(2)));
        assert!(s.median_secs() >= 0.002);
        assert_eq!(s.secs.len(), 3);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // just exercise formatting
    }
}
