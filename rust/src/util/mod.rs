//! Substrate utilities built from scratch (serde/criterion/proptest are not
//! available in this offline environment — see DESIGN.md §3.17).

pub mod backoff;
pub mod benchkit;
pub mod json;
pub mod rng;
pub mod stealing;
pub mod testutil;

/// Worker-thread count for host-side pack parallelism of ONE rank.
///
/// Rank threads of the simulated-MPI world share the machine, so the
/// default divides the hardware parallelism by `ranks_sharing` (keeping
/// ranks × workers ≈ cores instead of oversubscribing by a factor of the
/// rank count). `PARTHENON_NUM_THREADS` overrides the per-rank count
/// verbatim (deliberate oversubscription allowed); `cap` (usually the
/// pack count) always bounds the result.
pub fn num_workers(cap: usize, ranks_sharing: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let per_rank = (hw / ranks_sharing.max(1)).max(1);
    let n = std::env::var("PARTHENON_NUM_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(per_rank);
    n.min(cap.max(1)).max(1)
}
