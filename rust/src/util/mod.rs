//! Substrate utilities built from scratch (serde/criterion/proptest are not
//! available in this offline environment — see DESIGN.md §3.17).

pub mod benchkit;
pub mod json;
pub mod rng;
pub mod testutil;
