//! Bounded spin-then-backoff waiting.
//!
//! The simulated-MPI fabric delivers messages from sibling rank threads, so
//! waits are usually short — but an unbounded `yield_now` loop pegs a core
//! for the whole wait (and on oversubscribed machines actively steals cycles
//! from the rank that would unblock us). [`Backoff`] spins briefly for the
//! fast path, then yields, then sleeps with exponentially growing naps
//! capped at [`Backoff::MAX_NAP`].

use std::time::{Duration, Instant};

/// Escalating wait strategy: spin -> yield -> sleep.
#[derive(Debug, Default)]
pub struct Backoff {
    step: u32,
}

impl Backoff {
    /// Busy spins before the first yield.
    const SPIN_LIMIT: u32 = 32;
    /// Yields before the first sleep.
    const YIELD_LIMIT: u32 = 160;
    /// Sleep cap — keeps worst-case added latency small.
    pub const MAX_NAP: Duration = Duration::from_micros(500);

    pub fn new() -> Backoff {
        Backoff::default()
    }

    /// Back to the fast path (call after observing progress).
    pub fn reset(&mut self) {
        self.step = 0;
    }

    /// True once the strategy has escalated to sleeping.
    pub fn is_sleeping(&self) -> bool {
        self.step >= Self::YIELD_LIMIT
    }

    /// Wait one step, escalating the strategy.
    pub fn snooze(&mut self) {
        if self.step < Self::SPIN_LIMIT {
            std::hint::spin_loop();
        } else if self.step < Self::YIELD_LIMIT {
            std::thread::yield_now();
        } else {
            // exponential naps: 8us, 16us, ... capped at MAX_NAP
            let exp = (self.step - Self::YIELD_LIMIT).min(6);
            let nap = Duration::from_micros(8u64 << exp).min(Self::MAX_NAP);
            std::thread::sleep(nap);
        }
        self.step = self.step.saturating_add(1);
    }
}

/// Progress-aware waiter shared by every communication wait loop
/// (blocking exchange, flux correction, device routing): resets the
/// backoff *and* the stall watchdog whenever the caller observes
/// progress, snoozes when idle, and reports a stall only after `limit`
/// elapses with no progress at all.
#[derive(Debug)]
pub struct ProgressWait {
    backoff: Backoff,
    watchdog: Deadline,
    limit: Duration,
}

impl ProgressWait {
    pub fn new(limit: Duration) -> ProgressWait {
        ProgressWait {
            backoff: Backoff::new(),
            watchdog: Deadline::new(limit),
            limit,
        }
    }

    /// Record one poll round. Returns false once the wait has stalled
    /// (no progress for `limit`); otherwise waits one backoff step (only
    /// when idle) and returns true.
    pub fn step(&mut self, progressed: bool) -> bool {
        if progressed {
            self.backoff.reset();
            self.watchdog = Deadline::new(self.limit);
            return true;
        }
        if self.watchdog.expired() {
            return false;
        }
        self.backoff.snooze();
        true
    }

    /// Time since the last observed progress.
    pub fn idle_elapsed(&self) -> Duration {
        self.watchdog.elapsed()
    }
}

/// Wall-clock watchdog for stall detection (replaces raw spin counting,
/// whose meaning changed when waits stopped being pure busy-spins).
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    t0: Instant,
    limit: Duration,
}

impl Deadline {
    pub fn new(limit: Duration) -> Deadline {
        Deadline { t0: Instant::now(), limit }
    }

    pub fn expired(&self) -> bool {
        self.t0.elapsed() >= self.limit
    }

    pub fn elapsed(&self) -> Duration {
        self.t0.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escalates_to_sleep_and_resets() {
        let mut b = Backoff::new();
        assert!(!b.is_sleeping());
        for _ in 0..(Backoff::YIELD_LIMIT + 2) {
            b.snooze();
        }
        assert!(b.is_sleeping());
        b.reset();
        assert!(!b.is_sleeping());
    }

    #[test]
    fn naps_are_capped() {
        let mut b = Backoff::new();
        for _ in 0..(Backoff::YIELD_LIMIT + 20) {
            b.snooze();
        }
        // one more snooze must not exceed the cap by a large margin
        let t0 = Instant::now();
        b.snooze();
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn progress_wait_resets_on_progress_and_stalls_when_idle() {
        let mut pw = ProgressWait::new(Duration::from_millis(5));
        // progress keeps it alive past the idle limit
        for _ in 0..3 {
            std::thread::sleep(Duration::from_millis(3));
            assert!(pw.step(true));
        }
        // pure idling trips the watchdog
        let t0 = Instant::now();
        let mut stalled = false;
        while t0.elapsed() < Duration::from_secs(5) {
            if !pw.step(false) {
                stalled = true;
                break;
            }
        }
        assert!(stalled, "idle wait must stall after the limit");
    }

    #[test]
    fn deadline_expires() {
        let d = Deadline::new(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(3));
        assert!(d.expired());
        let d2 = Deadline::new(Duration::from_secs(3600));
        assert!(!d2.expired());
    }
}
