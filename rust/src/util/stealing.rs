//! Cost-aware work-stealing scheduler over pack-shaped work items.
//!
//! The unit of scheduling is an *item index* (a MeshBlockPack in the stage
//! loops, a task list in [`crate::tasks::TaskRegion::execute_parallel`]).
//! Items are seeded into per-worker deques by a contiguous, cost-weighted
//! partition — the same shape as `MeshData::worker_block_ranges`, but over
//! per-item costs — so with [`StealPolicy::NoSteal`] the pool degenerates
//! to the static cost-balanced schedule. With any other policy a worker
//! whose local deque drains steals from the *back* of a victim's deque
//! (victim order set by the policy), closing the tail that static dealing
//! leaves on multilevel meshes with uneven per-block cost.
//!
//! Determinism: the pool only decides *which worker* runs an item, never
//! *whether* or *how*; every item is claimed exactly once. Consumers keep
//! per-item writes disjoint (packs own disjoint block ranges), so results
//! are bitwise identical under any worker count and any steal order —
//! pinned by `rust/tests/sched_stealing.rs`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Victim-selection policy when a worker's own deque is empty.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StealPolicy {
    /// Never steal: the seeded partition is the final (static) schedule.
    NoSteal,
    /// Steal from the victim with the largest remaining queued cost.
    Heaviest,
    /// Forced order for tests: victims `w+1, w+2, ...` cyclically.
    RoundRobin,
    /// Forced order for tests: victims in descending worker index.
    Reverse,
}

impl StealPolicy {
    /// Parse the `parthenon/exec sched` input value.
    pub fn parse(s: &str) -> Option<StealPolicy> {
        match s {
            "static" | "nosteal" => Some(StealPolicy::NoSteal),
            "stealing" | "heaviest" => Some(StealPolicy::Heaviest),
            "roundrobin" | "round_robin" => Some(StealPolicy::RoundRobin),
            "reverse" => Some(StealPolicy::Reverse),
            _ => None,
        }
    }
}

/// Fixed-point cost unit (millicost) for the atomic load counters.
fn to_fp(c: f64) -> u64 {
    (c.max(0.0) * 1000.0).round() as u64 + 1 // +1: every item has weight
}

/// A shared pool of item indices, one deque per worker.
pub struct StealPool {
    queues: Vec<Mutex<VecDeque<usize>>>,
    /// Remaining queued cost per worker (advisory, for victim selection).
    loads: Vec<AtomicU64>,
    /// Per-item fixed-point cost.
    costs: Vec<u64>,
    /// The seed-time partition: which items each worker's deque started
    /// with. Immutable after seeding — consumers use it to attribute a
    /// worker to the "home" group of its seeded items (e.g. the hybrid
    /// scheduler's cross-space steal accounting).
    seeds: Vec<Vec<usize>>,
    policy: StealPolicy,
    steals: AtomicUsize,
}

impl StealPool {
    /// Seed `costs.len()` items into `nworkers` deques by contiguous
    /// cost-weighted partition (worker `w` gets a contiguous run of item
    /// indices whose summed cost is ~`total / nworkers`).
    pub fn seed(costs: &[f64], nworkers: usize, policy: StealPolicy) -> StealPool {
        let n = costs.len();
        let nw = nworkers.max(1);
        let fp: Vec<u64> = costs.iter().map(|&c| to_fp(c)).collect();
        let mut queues: Vec<VecDeque<usize>> = (0..nw).map(|_| VecDeque::new()).collect();
        let mut loads = vec![0u64; nw];
        let mut remaining: u64 = fp.iter().sum();
        let mut i = 0usize;
        for w in 0..nw {
            if i >= n {
                break;
            }
            let workers_left = (nw - w) as u64;
            let target = (remaining + workers_left - 1) / workers_left; // ceil
            let mut got = 0u64;
            loop {
                queues[w].push_back(i);
                loads[w] += fp[i];
                got += fp[i];
                i += 1;
                if i >= n {
                    break;
                }
                // leave at least one item for every later worker
                if (n - i) as u64 <= workers_left - 1 {
                    break;
                }
                if got >= target {
                    break;
                }
            }
            remaining -= got;
        }
        debug_assert_eq!(i, n);
        let seeds: Vec<Vec<usize>> =
            queues.iter().map(|q| q.iter().copied().collect()).collect();
        StealPool {
            queues: queues.into_iter().map(Mutex::new).collect(),
            loads: loads.into_iter().map(AtomicU64::new).collect(),
            costs: fp,
            seeds,
            policy,
            steals: AtomicUsize::new(0),
        }
    }

    pub fn nworkers(&self) -> usize {
        self.queues.len()
    }

    /// Total number of items the pool was seeded with.
    pub fn nitems(&self) -> usize {
        self.costs.len()
    }

    /// Steals performed so far (instrumentation).
    pub fn steals(&self) -> usize {
        self.steals.load(Ordering::SeqCst)
    }

    /// The items worker `w`'s deque was seeded with (seed-time snapshot;
    /// stealing does not rewrite it).
    pub fn seeded(&self, w: usize) -> &[usize] {
        &self.seeds[w]
    }

    /// Re-queue an item onto worker `w`'s own deque (task-region polling:
    /// an incomplete list goes back to the holder's queue, where idle
    /// workers may steal it).
    pub fn push(&self, w: usize, item: usize) {
        self.queues[w].lock().unwrap().push_back(item);
        self.loads[w].fetch_add(self.costs[item], Ordering::SeqCst);
    }

    /// Claim the next item for worker `w`: own deque front first, then — if
    /// the policy allows — the back of a victim's deque. `None` means every
    /// deque was empty at scan time (not necessarily global completion when
    /// items can be re-queued).
    pub fn claim(&self, w: usize) -> Option<usize> {
        self.claim2(w).map(|(i, _stolen)| i)
    }

    /// [`StealPool::claim`] that also reports WHERE the item came from:
    /// `(item, true)` when it was stolen from a victim's deque, `(item,
    /// false)` when it came from worker `w`'s own deque. The flag feeds the
    /// hybrid scheduler's cross-space steal counters.
    pub fn claim2(&self, w: usize) -> Option<(usize, bool)> {
        if let Some(i) = self.queues[w].lock().unwrap().pop_front() {
            self.loads[w].fetch_sub(self.costs[i], Ordering::SeqCst);
            return Some((i, false));
        }
        if self.policy == StealPolicy::NoSteal {
            return None;
        }
        for v in self.victim_order(w) {
            if let Some(i) = self.queues[v].lock().unwrap().pop_back() {
                self.loads[v].fetch_sub(self.costs[i], Ordering::SeqCst);
                self.steals.fetch_add(1, Ordering::SeqCst);
                return Some((i, true));
            }
        }
        None
    }

    /// Victim scan order for worker `w` under the pool's policy.
    fn victim_order(&self, w: usize) -> Vec<usize> {
        let nq = self.queues.len();
        match self.policy {
            StealPolicy::NoSteal => Vec::new(),
            StealPolicy::Heaviest => {
                // advisory load snapshot, heaviest first
                let mut vs: Vec<usize> = (0..nq).filter(|&v| v != w).collect();
                vs.sort_by_key(|&v| std::cmp::Reverse(self.loads[v].load(Ordering::SeqCst)));
                vs
            }
            StealPolicy::RoundRobin => (1..nq).map(|d| (w + d) % nq).collect(),
            StealPolicy::Reverse => (0..nq).rev().filter(|&v| v != w).collect(),
        }
    }
}

/// Run one item per claim over the pool with per-worker state: worker `w`
/// executes `f(&mut states[w], item_index, item)` for every item it claims.
/// Items are handed out exactly once; per-item payloads carry the mutable
/// chunks (disjoint by construction), so no locking happens inside `f`.
///
/// `states.len()` must equal `pool.nworkers()`. With one worker everything
/// runs inline on the caller's thread (no spawn overhead).
pub fn run_stealing<T, S, F>(pool: &StealPool, items: Vec<T>, states: &mut [S], f: F)
where
    T: Send,
    S: Send,
    F: Fn(&mut S, usize, T) + Sync,
{
    assert_eq!(items.len(), pool.nitems(), "one payload per seeded item");
    assert_eq!(states.len(), pool.nworkers(), "one state per worker");
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let nw = pool.nworkers();
    if nw <= 1 {
        let s = &mut states[0];
        while let Some(i) = pool.claim(0) {
            if let Some(t) = slots[i].lock().unwrap().take() {
                f(s, i, t);
            }
        }
        return;
    }
    let slots = &slots;
    let f = &f;
    std::thread::scope(|scope| {
        for (w, s) in states.iter_mut().enumerate() {
            scope.spawn(move || {
                while let Some(i) = pool.claim(w) {
                    if let Some(t) = slots[i].lock().unwrap().take() {
                        f(s, i, t);
                    }
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn every_item_claimed_exactly_once() {
        for policy in [
            StealPolicy::NoSteal,
            StealPolicy::Heaviest,
            StealPolicy::RoundRobin,
            StealPolicy::Reverse,
        ] {
            let costs = vec![1.0; 23];
            let pool = StealPool::seed(&costs, 4, policy);
            let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
            let items: Vec<usize> = (0..23).collect();
            let mut states = vec![(); 4];
            run_stealing(&pool, items, &mut states, |_s, idx, item| {
                assert_eq!(idx, item);
                hits[item].fetch_add(1, Ordering::SeqCst);
            });
            for h in &hits {
                assert_eq!(h.load(Ordering::SeqCst), 1, "policy {policy:?}");
            }
        }
    }

    #[test]
    fn seeding_is_cost_weighted_and_contiguous() {
        // one hot item: it should own a whole worker's queue
        let mut costs = vec![1.0; 9];
        costs[0] = 100.0;
        let pool = StealPool::seed(&costs, 2, StealPolicy::NoSteal);
        let q0: Vec<usize> = pool.queues[0].lock().unwrap().iter().copied().collect();
        let q1: Vec<usize> = pool.queues[1].lock().unwrap().iter().copied().collect();
        assert_eq!(q0, vec![0], "hot item fills worker 0");
        assert_eq!(q1, (1..9).collect::<Vec<_>>());
        // contiguity + coverage in the uniform case
        let pool = StealPool::seed(&vec![1.0; 10], 3, StealPolicy::NoSteal);
        let mut all = Vec::new();
        for q in &pool.queues {
            let items: Vec<usize> = q.lock().unwrap().iter().copied().collect();
            for w in items.windows(2) {
                assert_eq!(w[1], w[0] + 1, "queues hold contiguous runs");
            }
            all.extend(items);
        }
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn skewed_seed_triggers_steals() {
        // worker 0 gets nearly everything; worker 1 must steal to help
        let mut costs = vec![0.001; 64];
        costs[63] = 1000.0; // forces the partition to give w1 only the tail
        let pool = StealPool::seed(&costs, 2, StealPolicy::Heaviest);
        let items: Vec<usize> = (0..64).collect();
        let mut states = vec![(); 2];
        run_stealing(&pool, items, &mut states, |_s, _i, _t| {
            // simulate work so the second worker outlives its own queue
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert!(pool.steals() > 0, "idle worker must have stolen");
    }

    #[test]
    fn nosteal_never_steals() {
        let pool = StealPool::seed(&vec![1.0; 16], 4, StealPolicy::NoSteal);
        let items: Vec<usize> = (0..16).collect();
        let mut states = vec![(); 4];
        run_stealing(&pool, items, &mut states, |_s, _i, _t| {});
        assert_eq!(pool.steals(), 0);
    }

    #[test]
    fn seeds_recorded_and_claim2_flags_steals() {
        let pool = StealPool::seed(&vec![1.0; 6], 2, StealPolicy::RoundRobin);
        assert_eq!(pool.seeded(0), &[0, 1, 2]);
        assert_eq!(pool.seeded(1), &[3, 4, 5]);
        // own-deque claims are not steals
        let (i, stolen) = pool.claim2(0).unwrap();
        assert_eq!((i, stolen), (0, false));
        // drain worker 1's deque, then its next claim must steal from 0
        for _ in 0..3 {
            let (_, s) = pool.claim2(1).unwrap();
            assert!(!s);
        }
        let (i, stolen) = pool.claim2(1).unwrap();
        assert!(stolen, "victim-deque claim must be flagged");
        assert_eq!(i, 2, "steals come from the back of the victim deque");
        assert_eq!(pool.seeded(0), &[0, 1, 2], "seed snapshot is immutable");
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(StealPolicy::parse("static"), Some(StealPolicy::NoSteal));
        assert_eq!(StealPolicy::parse("stealing"), Some(StealPolicy::Heaviest));
        assert_eq!(StealPolicy::parse("roundrobin"), Some(StealPolicy::RoundRobin));
        assert_eq!(StealPolicy::parse("reverse"), Some(StealPolicy::Reverse));
        assert_eq!(StealPolicy::parse("bogus"), None);
    }

    #[test]
    fn more_workers_than_items() {
        let pool = StealPool::seed(&vec![1.0; 2], 8, StealPolicy::Heaviest);
        let hits: Vec<AtomicUsize> = (0..2).map(|_| AtomicUsize::new(0)).collect();
        let mut states = vec![(); 8];
        run_stealing(&pool, vec![0usize, 1], &mut states, |_s, _i, t| {
            hits[t].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }
}
