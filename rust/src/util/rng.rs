//! Deterministic xorshift RNG — used by problem generators, tests and the
//! property-testing harness (rand crates unavailable offline; determinism is
//! a feature here anyway: restarts must be bit-reproducible).

/// xorshift64* generator.
#[derive(Clone, Debug)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        XorShift {
            state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// true with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift::new(7);
        let mut b = XorShift::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift::new(3);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = XorShift::new(4);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = XorShift::new(5);
        let mut buckets = [0usize; 10];
        for _ in 0..10_000 {
            buckets[r.below(10)] += 1;
        }
        for b in buckets {
            assert!((700..1300).contains(&b), "bucket count {b}");
        }
    }
}
