//! Minimal JSON reader/writer.
//!
//! Used for the artifact manifest (written by python/compile/aot.py), bench
//! result files, and pbin output headers.  Supports the full JSON value
//! grammar minus exotic number forms; good enough for machine-generated
//! documents, which is all we parse.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.i
            ))),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(Error::Json(format!("bad object at {}", self.i))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(Error::Json(format!("bad array at {}", self.i))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| Error::Json("bad \\u".into()))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::Json("bad \\u".into()))?,
                                16,
                            )
                            .map_err(|_| Error::Json("bad \\u".into()))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(Error::Json("bad escape".into())),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| Error::Json("invalid utf8".into()))?;
                    s.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Json(format!("bad number {txt:?}: {e}")))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": -1.5e3}"#).unwrap();
        assert_eq!(v.req("c").unwrap().as_f64(), Some(-1500.0));
        let arr = v.req("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].req("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn builder_obj() {
        let v = obj(vec![("x", 1usize.into()), ("y", "z".into())]);
        assert_eq!(v.req("x").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"π≈3.14159\"").unwrap();
        assert_eq!(v.as_str(), Some("π≈3.14159"));
    }
}
