//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` random seeds;
//! on failure it reports the failing seed so the case can be replayed as a
//! deterministic regression (`replay(seed, f)`).

use super::rng::XorShift;

/// Run `f` for `cases` pseudo-random cases. Panics with the failing seed.
pub fn check<F: FnMut(&mut XorShift)>(name: &str, cases: usize, mut f: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = XorShift::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed at case {case} (seed {seed:#x}); \
                 replay with testutil::replay({seed:#x}, f)"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay one failing case.
pub fn replay<F: FnMut(&mut XorShift)>(seed: u64, mut f: F) {
    let mut rng = XorShift::new(seed);
    f(&mut rng);
}

/// Assert two f32 slices are close (absolute + relative tolerance).
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "element {i}: {x} vs {y} (|diff|={}, tol={tol})",
            (x - y).abs()
        );
    }
}

/// Max absolute difference between two slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("count", 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check("fail", 10, |rng| assert!(rng.next_f64() < 0.5));
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6);
    }

    #[test]
    #[should_panic]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[1.1], 1e-5, 1e-6);
    }
}
