//! # parthenon-rs
//!
//! A performance-portable block-structured adaptive mesh refinement (AMR)
//! framework — a reproduction of *"Parthenon — a performance portable
//! block-structured adaptive mesh refinement framework"* (Grete et al. 2022)
//! as a three-layer Rust + JAX/Pallas (AOT via PJRT) stack.
//!
//! Layer map (see DESIGN.md):
//! * **L3 (this crate)** — the framework: mesh/tree, variables/packages,
//!   boundary communication with buffer/block packing, simulated MPI,
//!   tasking, load balancing, drivers, IO, particles.
//! * **L2/L1 (python/compile)** — the PARTHENON-HYDRO compute hot path
//!   (RK2 + PLM + HLLE) as a JAX graph / Pallas kernel, AOT-lowered to HLO
//!   text and executed from [`runtime`] through the PJRT CPU client.
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.

// CI gates on `cargo clippy --release -- -D warnings`. These stylistic
// lints fight the numeric-kernel idiom this crate is written in (flat-array
// loops indexed by (k, j, i), kernels whose signatures mirror the artifact
// ABI, pack/slice plumbing with necessarily chunky types) — allowed
// crate-wide so the gate stays about real defects.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::manual_memcpy,
    clippy::new_without_default,
    clippy::result_large_err
)]

pub mod balance;
pub mod bvals;
pub mod comm;
pub mod config;
pub mod driver;
pub mod error;
pub mod hydro;
pub mod io;
pub mod mesh;
pub mod mesh_data;
pub mod metrics;
pub mod particles;
pub mod runtime;
pub mod service;
pub mod tasks;
pub mod util;
pub mod vars;

/// Floating-point type of the compute hot path (matches artifact dtype).
pub type Real = f32;

/// Number of ghost cells in every active dimension (PLM stencil depth).
pub const NGHOST: usize = 2;

/// Number of conserved hydro variables (rho, mx, my, mz, E).
pub const NHYDRO: usize = 5;

pub use error::{Error, Result};

/// Commonly used items, one `use` away.
pub mod prelude {
    pub use crate::config::ParameterInput;
    pub use crate::error::{Error, Result};
    pub use crate::mesh::{LogicalLocation, Mesh, MeshBlock};
    pub use crate::vars::{Metadata, MetadataFlag, Params, StateDescriptor};
    pub use crate::{Real, NGHOST, NHYDRO};
}
