//! Micro-benchmarks of the mesh substrate: tree construction, neighbor
//! resolution, Morton sort, regrid, and load-balance assignment — the
//! "mesh management overhead" the paper attributes CPU overdecomposition
//! costs to (Sec. 5.1/5.2).

use std::collections::HashMap;

use parthenon::balance;
use parthenon::mesh::{AmrFlag, BlockTree};
use parthenon::util::benchkit::{quick_mode, run, write_results, Table};

fn main() {
    let quick = quick_mode();
    let nrb: i64 = if quick { 8 } else { 16 };
    let mut samples = Vec::new();
    let mut table = Table::new(&["micro-benchmark", "median", "rate"]);

    // uniform construction
    let s = run("tree_build", (nrb * nrb * nrb) as f64, 2, 7, || {
        let t = BlockTree::uniform([nrb, nrb, nrb], 3, [true; 3]);
        std::hint::black_box(t.nblocks());
    });
    table.row(vec![
        format!("tree build ({0}^3 = {1} blocks)", nrb, nrb * nrb * nrb),
        format!("{:.2} ms", s.median_secs() * 1e3),
        format!("{:.1}M blocks/s", s.throughput() / 1e6),
    ]);
    samples.push(s);

    // neighbor resolution over the whole tree
    let tree = BlockTree::uniform([nrb, nrb, nrb], 3, [true; 3]);
    let nblocks = tree.nblocks();
    let t2 = tree.clone();
    let s = run("neighbors", (nblocks * 26) as f64, 2, 7, move || {
        let mut count = 0usize;
        for l in t2.leaves() {
            count += t2.find_neighbors(l).len();
        }
        std::hint::black_box(count);
    });
    table.row(vec![
        "neighbor resolution (all leaves)".into(),
        format!("{:.2} ms", s.median_secs() * 1e3),
        format!("{:.1}M nbrs/s", s.throughput() / 1e6),
    ]);
    samples.push(s);

    // regrid with a refining central region
    let t3 = tree.clone();
    let s = run("regrid", nblocks as f64, 1, 5, move || {
        let mut flags = HashMap::new();
        for l in t3.leaves() {
            let c = nrb / 2;
            let hit = (l.lx[0] - c).abs() <= 1 && (l.lx[1] - c).abs() <= 1 && (l.lx[2] - c).abs() <= 1;
            flags.insert(*l, if hit { AmrFlag::Refine } else { AmrFlag::Same });
        }
        let t = t3.regrid(&flags, 2);
        std::hint::black_box(t.nblocks());
    });
    table.row(vec![
        "regrid (central cube refines)".into(),
        format!("{:.2} ms", s.median_secs() * 1e3),
        format!("{:.1}M blocks/s", s.throughput() / 1e6),
    ]);
    samples.push(s);

    // balance assignment
    let costs: Vec<f64> = (0..nblocks).map(|i| 1.0 + (i % 7) as f64 * 0.1).collect();
    let s = run("balance", nblocks as f64, 2, 9, move || {
        let a = balance::assign_blocks(&costs, 64);
        std::hint::black_box(a.len());
    });
    table.row(vec![
        "balance (64 ranks)".into(),
        format!("{:.3} ms", s.median_secs() * 1e3),
        format!("{:.1}M blocks/s", s.throughput() / 1e6),
    ]);
    samples.push(s);

    // coverage check (invariant validation cost)
    let t4 = tree.clone();
    let s = run("coverage", nblocks as f64, 1, 3, move || {
        t4.check_coverage().unwrap();
    });
    table.row(vec![
        "coverage check".into(),
        format!("{:.2} ms", s.median_secs() * 1e3),
        format!("{:.1}M blocks/s", s.throughput() / 1e6),
    ]);
    samples.push(s);

    println!();
    table.print();
    write_results("micro_mesh", &samples, vec![("quick", quick.into())]);
}
