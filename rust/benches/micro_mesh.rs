//! Micro-benchmarks of the mesh substrate: tree construction, neighbor
//! resolution, Morton sort, regrid, load-balance assignment, and the
//! end-to-end churn-rebalance cost (full oracle vs. incremental delta
//! migration) — the "mesh management overhead" the paper attributes CPU
//! overdecomposition costs to (Sec. 5.1/5.2).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use parthenon::balance;
use parthenon::comm::World;
use parthenon::config::ParameterInput;
use parthenon::driver::{regrid, EvolutionDriver, SimBuilder};
use parthenon::mesh::{AmrFlag, BlockTree};
use parthenon::util::benchkit::{quick_mode, run, write_results, Sample, Table};

/// End-to-end cost of a 2-rank churn rebalance (blocks shuttling between
/// the ranks every call) under the given `parthenon/loadbalance mode`.
/// Only the `regrid::rebalance` calls are timed — sim construction and the
/// warm-up steps stay outside the samples — so the row isolates exactly
/// the migration overhead the incremental path attacks. Work units =
/// blocks moved per rep, giving perf_compare a moved-blocks/s throughput.
fn bench_churn_rebalance(mode: &str, nx: usize, reps: usize, churns: usize) -> Sample {
    let deck = format!(
        "<parthenon/job>\nproblem = kh\nquiet = true\n\n\
         <parthenon/mesh>\nnx1 = {nx}\nnx2 = {nx}\n\n\
         <parthenon/meshblock>\nnx1 = 8\nnx2 = 8\n\n\
         <parthenon/time>\ntlim = 100.0\nnlim = -1\n\n\
         <parthenon/loadbalance>\nmode = {mode}\n\n\
         <hydro>\ngamma = 1.4\ncfl = 0.3\n"
    );
    let secs: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let moved: Arc<Mutex<u64>> = Arc::new(Mutex::new(0));
    let (s2, m2) = (secs.clone(), moved.clone());
    World::launch(2, move |rank, world| {
        let pin = ParameterInput::from_str(&deck).unwrap();
        let mut sim =
            SimBuilder::new(pin).rank(rank).world(world).build().unwrap();
        sim.step().unwrap(); // warm the caches and the cost EWMA
        // shuttle the boundary between the ranks: alternate two cuts a
        // few blocks apart so every churn migrates the same delta
        let nblocks = sim.mesh.ranks.len();
        let cut0 = sim.mesh.ranks.iter().filter(|&&r| r == 0).count();
        let cut1 = cut0.saturating_sub(2).max(1);
        for rep in 0..reps + 1 {
            let t0 = std::time::Instant::now();
            for churn in 0..churns {
                let cut = if churn % 2 == 0 { cut1 } else { cut0 };
                let new_ranks: Vec<usize> =
                    (0..nblocks).map(|g| usize::from(g >= cut)).collect();
                regrid::rebalance(&mut sim, new_ranks).unwrap();
            }
            if rank == 0 && rep > 0 {
                s2.lock().unwrap().push(t0.elapsed().as_secs_f64());
            }
        }
        if rank == 0 {
            *m2.lock().unwrap() = sim.lb_stats.blocks_moved;
        }
    });
    let secs = Arc::try_unwrap(secs).unwrap().into_inner().unwrap();
    let total_moved = *moved.lock().unwrap() as f64;
    Sample {
        label: format!("rebalance/{mode}"),
        secs,
        // blocks moved per rep (the first, untimed rep is the warmup)
        work: total_moved / (reps + 1) as f64,
    }
}

fn main() {
    let quick = quick_mode();
    let nrb: i64 = if quick { 8 } else { 16 };
    let mut samples = Vec::new();
    let mut table = Table::new(&["micro-benchmark", "median", "rate"]);

    // uniform construction
    let s = run("tree_build", (nrb * nrb * nrb) as f64, 2, 7, || {
        let t = BlockTree::uniform([nrb, nrb, nrb], 3, [true; 3]);
        std::hint::black_box(t.nblocks());
    });
    table.row(vec![
        format!("tree build ({0}^3 = {1} blocks)", nrb, nrb * nrb * nrb),
        format!("{:.2} ms", s.median_secs() * 1e3),
        format!("{:.1}M blocks/s", s.throughput() / 1e6),
    ]);
    samples.push(s);

    // neighbor resolution over the whole tree
    let tree = BlockTree::uniform([nrb, nrb, nrb], 3, [true; 3]);
    let nblocks = tree.nblocks();
    let t2 = tree.clone();
    let s = run("neighbors", (nblocks * 26) as f64, 2, 7, move || {
        let mut count = 0usize;
        for l in t2.leaves() {
            count += t2.find_neighbors(l).len();
        }
        std::hint::black_box(count);
    });
    table.row(vec![
        "neighbor resolution (all leaves)".into(),
        format!("{:.2} ms", s.median_secs() * 1e3),
        format!("{:.1}M nbrs/s", s.throughput() / 1e6),
    ]);
    samples.push(s);

    // regrid with a refining central region
    let t3 = tree.clone();
    let s = run("regrid", nblocks as f64, 1, 5, move || {
        let mut flags = HashMap::new();
        for l in t3.leaves() {
            let c = nrb / 2;
            let hit = (l.lx[0] - c).abs() <= 1 && (l.lx[1] - c).abs() <= 1 && (l.lx[2] - c).abs() <= 1;
            flags.insert(*l, if hit { AmrFlag::Refine } else { AmrFlag::Same });
        }
        let t = t3.regrid(&flags, 2);
        std::hint::black_box(t.nblocks());
    });
    table.row(vec![
        "regrid (central cube refines)".into(),
        format!("{:.2} ms", s.median_secs() * 1e3),
        format!("{:.1}M blocks/s", s.throughput() / 1e6),
    ]);
    samples.push(s);

    // balance assignment
    let costs: Vec<f64> = (0..nblocks).map(|i| 1.0 + (i % 7) as f64 * 0.1).collect();
    let s = run("balance", nblocks as f64, 2, 9, move || {
        let a = balance::assign_blocks(&costs, 64);
        std::hint::black_box(a.len());
    });
    table.row(vec![
        "balance (64 ranks)".into(),
        format!("{:.3} ms", s.median_secs() * 1e3),
        format!("{:.1}M blocks/s", s.throughput() / 1e6),
    ]);
    samples.push(s);

    // coverage check (invariant validation cost)
    let t4 = tree.clone();
    let s = run("coverage", nblocks as f64, 1, 3, move || {
        t4.check_coverage().unwrap();
    });
    table.row(vec![
        "coverage check".into(),
        format!("{:.2} ms", s.median_secs() * 1e3),
        format!("{:.1}M blocks/s", s.throughput() / 1e6),
    ]);
    samples.push(s);

    // churn rebalance: 2-rank sim, a fixed block delta shuttling between
    // the ranks — full oracle vs. incremental delta migration. These rows
    // feed the CI regrid perf lane (perf_compare --tol 0.2, baseline v4).
    let (nx, reps, churns) = if quick { (32, 5, 4) } else { (64, 9, 8) };
    for mode in ["full", "incremental"] {
        let s = bench_churn_rebalance(mode, nx, reps, churns);
        table.row(vec![
            format!("churn rebalance ({mode}, {nx}x{nx}, {churns} churns)"),
            format!("{:.2} ms", s.median_secs() * 1e3),
            format!("{:.2}k moved blocks/s", s.throughput() / 1e3),
        ]);
        samples.push(s);
    }

    println!();
    table.print();
    write_results("micro_mesh", &samples, vec![("quick", quick.into())]);
}
