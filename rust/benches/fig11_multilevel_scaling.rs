//! FIG. 11 — Strong scaling on a multilevel grid.
//!
//! Paper: 256^3 root grid, 32^3 blocks, 3 refined levels (24,816 blocks);
//! the prolongation/restriction + flux-correction machinery is live, so
//! efficiency is lower than the uniform case (GPU ~59% for 16x on Summit).
//!
//! Here: 32^3 root grid, 8^3 blocks, a centrally refined cube (2 levels),
//! Host path (multilevel; Device is uniform-only — DESIGN.md), ranks 1..8.
//! Compare the efficiency decline against fig10's uniform host column: the
//! multilevel mesh pays extra for flux correction + prolong/restrict,
//! reproducing the paper's uniform-vs-multilevel gap.

use parthenon::driver::bench::{deck_multilevel, measure};
use parthenon::util::benchkit::{fmt_zcps, quick_mode, write_results, Sample, Table};

fn main() {
    let quick = quick_mode();
    let meas = if quick { 1 } else { 3 };
    let root = if quick { 16 } else { 32 };
    let levels = if quick { 1 } else { 2 };
    let ranks_list: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };

    let deck = deck_multilevel(root, 8, levels);
    println!("== Fig 11: multilevel strong scaling (root {root}^3, 8^3 blocks, {levels} levels) ==\n");

    let mut samples = Vec::new();
    let mut table = Table::new(&["ranks", "blocks", "zc/s", "efficiency"]);
    let mut base = 0.0f64;
    for &r in ranks_list {
        let run = measure(&deck, &[], r, 1, meas);
        if r == ranks_list[0] {
            base = run.zcps;
        }
        table.row(vec![
            r.to_string(),
            run.nblocks.to_string(),
            fmt_zcps(run.zcps),
            format!("{:.2}", run.zcps / base),
        ]);
        samples.push(Sample {
            label: format!("multilevel/r{r}"),
            secs: vec![run.wall / run.cycles as f64],
            work: run.zcps * run.wall / run.cycles as f64,
        });
        eprintln!("  ranks {r}: {} zc/s ({} blocks)", fmt_zcps(run.zcps), run.nblocks);
    }
    println!();
    table.print();
    write_results(
        "fig11_multilevel_scaling",
        &samples,
        vec![("quick", quick.into()), ("root", (root as i64).into())],
    );
}
