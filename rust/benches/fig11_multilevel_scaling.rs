//! FIG. 11 — Strong scaling on a multilevel grid.
//!
//! Paper: 256^3 root grid, 32^3 blocks, 3 refined levels (24,816 blocks);
//! the prolongation/restriction + flux-correction machinery is live, so
//! efficiency is lower than the uniform case (GPU ~59% for 16x on Summit).
//!
//! Here: 32^3 root grid, 8^3 blocks, a centrally refined cube (2 levels),
//! ranks 1..8, then an execution-space sweep on the same multilevel deck
//! (the Device general-mode path runs multilevel meshes — DESIGN.md §4).
//! Compare the efficiency decline against fig10's uniform host column: the
//! multilevel mesh pays extra for flux correction + prolong/restrict,
//! reproducing the paper's uniform-vs-multilevel gap.

use parthenon::driver::bench::{deck_3d, deck_multilevel, measure};
use parthenon::util::benchkit::{fmt_zcps, quick_mode, write_results, Sample, Table};

fn main() {
    let quick = quick_mode();
    let meas = if quick { 1 } else { 3 };
    let root = if quick { 16 } else { 32 };
    let levels = if quick { 1 } else { 2 };
    let ranks_list: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };

    let deck = deck_multilevel(root, 8, levels);
    println!("== Fig 11: multilevel strong scaling (root {root}^3, 8^3 blocks, {levels} levels) ==\n");

    let mut samples = Vec::new();
    let mut table = Table::new(&["ranks", "blocks", "zc/s", "efficiency"]);
    let mut base = 0.0f64;
    for &r in ranks_list {
        let run = measure(&deck, &[], r, 1, meas);
        if r == ranks_list[0] {
            base = run.zcps;
        }
        table.row(vec![
            r.to_string(),
            run.nblocks.to_string(),
            fmt_zcps(run.zcps),
            format!("{:.2}", run.zcps / base),
        ]);
        samples.push(Sample {
            label: format!("multilevel/r{r}"),
            secs: vec![run.wall / run.cycles as f64],
            work: run.zcps * run.wall / run.cycles as f64,
        });
        eprintln!("  ranks {r}: {} zc/s ({} blocks)", fmt_zcps(run.zcps), run.nblocks);
    }
    println!();
    table.print();

    // -- cost-aware scheduling: static ranges vs work-stealing ---------------
    // Single rank, multilevel mesh (uneven per-block cost once the EWMA has
    // warmed up), worker-count sweep at pack_size 2 so the pool has enough
    // packs to deal AND steal. The acceptance metric for the stealing
    // executor: >= 15% over static at 8 workers on this shape
    // (`sched/{static,steal}/w8` in the JSON).
    let nworkers_list: &[usize] = if quick { &[2] } else { &[2, 4, 8] };
    let mut table_s = Table::new(&["nworkers", "static", "stealing", "speedup"]);
    println!("\nScheduler comparison (multilevel, 1 rank, pack_size 2):");
    for &nw in nworkers_list {
        let mut row = vec![format!("w={nw}")];
        let mut zc = [0.0f64; 2];
        for (si, sched) in ["static", "stealing"].iter().enumerate() {
            let ovs = [
                format!("parthenon/exec/sched={sched}"),
                format!("parthenon/exec/nworkers={nw}"),
                "parthenon/exec/pack_size=2".to_string(),
            ];
            let ov_refs: Vec<&str> = ovs.iter().map(|s| s.as_str()).collect();
            // extra warmup cycles so the cost EWMA informs the seed
            let run = measure(&deck, &ov_refs, 1, 3, meas.max(2));
            zc[si] = run.zcps;
            row.push(fmt_zcps(run.zcps));
            let label = if *sched == "static" { "static" } else { "steal" };
            samples.push(Sample {
                label: format!("sched/{label}/w{nw}"),
                secs: vec![run.wall / run.cycles as f64],
                work: run.zcps * run.wall / run.cycles as f64,
            });
            eprintln!("  sched {sched} w{nw}: {} zc/s", fmt_zcps(run.zcps));
        }
        row.push(format!("{:.2}x", zc[1] / zc[0].max(1e-30)));
        table_s.row(row);
    }
    table_s.print();

    // -- overlap: phased barriers vs fused per-pack pipeline -----------------
    // Same multilevel shape (flux correction + ghost exchange are live), so
    // the fused schedule can hide one pack's boundary communication behind
    // another pack's compute. `overlap/{phased,fused}` samples flow into
    // the per-runner perf baseline (tools.perf_compare), so an overlap
    // regression fails CI.
    let mut table_o = Table::new(&["nworkers", "phased", "fused", "speedup"]);
    println!("\nOverlap comparison (multilevel, 1 rank, pack_size 2, sched=stealing):");
    for &nw in nworkers_list {
        let mut row = vec![format!("w={nw}")];
        let mut zc = [0.0f64; 2];
        for (oi, mode) in ["phased", "fused"].iter().enumerate() {
            let ovs = [
                format!("parthenon/exec/overlap={mode}"),
                "parthenon/exec/sched=stealing".to_string(),
                format!("parthenon/exec/nworkers={nw}"),
                "parthenon/exec/pack_size=2".to_string(),
            ];
            let ov_refs: Vec<&str> = ovs.iter().map(|s| s.as_str()).collect();
            let run = measure(&deck, &ov_refs, 1, 3, meas.max(2));
            zc[oi] = run.zcps;
            row.push(fmt_zcps(run.zcps));
            samples.push(Sample {
                label: format!("overlap/{mode}/w{nw}"),
                secs: vec![run.wall / run.cycles as f64],
                work: run.zcps * run.wall / run.cycles as f64,
            });
            eprintln!("  overlap {mode} w{nw}: {} zc/s", fmt_zcps(run.zcps));
        }
        row.push(format!("{:.2}x", zc[1] / zc[0].max(1e-30)));
        table_o.row(row);
    }
    table_o.print();

    // -- Device fused pipeline: worker-parallel pack launches ----------------
    // The shared-state Runtime lets the fused per-pack task lists run on
    // N workers (launch → send → poll per pack, dt reduction regional), so
    // the Device path now has the same nworkers knob as the Host path.
    // Uniform periodic mesh (the Device configuration), pack_size 2 so the
    // pool has enough per-pack lists to deal AND steal. These
    // `device/{static,steal}/w{n}` samples feed the per-runner perf
    // baseline: a worker-scaling regression on the launch path fails CI.
    let dev_workers: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let dev_deck = deck_3d(if quick { 16 } else { 32 }, 8);
    let mut table_d = Table::new(&["nworkers", "static", "stealing", "speedup"]);
    println!("\nDevice fused worker scaling (uniform, 1 rank, pack_size 2):");
    for &nw in dev_workers {
        let mut row = vec![format!("w={nw}")];
        let mut zc = [0.0f64; 2];
        for (si, sched) in ["static", "stealing"].iter().enumerate() {
            let ovs = [
                "parthenon/exec/space=device".to_string(),
                "parthenon/exec/overlap=fused".to_string(),
                format!("parthenon/exec/sched={sched}"),
                format!("parthenon/exec/nworkers={nw}"),
                "parthenon/exec/pack_size=2".to_string(),
            ];
            let ov_refs: Vec<&str> = ovs.iter().map(|s| s.as_str()).collect();
            let run = measure(&dev_deck, &ov_refs, 1, 2, meas.max(2));
            zc[si] = run.zcps;
            row.push(fmt_zcps(run.zcps));
            let label = if *sched == "static" { "static" } else { "steal" };
            samples.push(Sample {
                label: format!("device/{label}/w{nw}"),
                secs: vec![run.wall / run.cycles as f64],
                work: run.zcps * run.wall / run.cycles as f64,
            });
            eprintln!("  device {sched} w{nw}: {} zc/s", fmt_zcps(run.zcps));
        }
        row.push(format!("{:.2}x", zc[1] / zc[0].max(1e-30)));
        table_d.row(row);
    }
    table_d.print();

    // -- execution spaces: host vs device vs cost-partitioned hybrid ---------
    // Same uniform deck; the hybrid row forces a 50/50 pack split so the
    // perf lane measures TRUE co-execution (one TaskRegion, both spaces),
    // and its HybridStats counter dump is asserted live — a refactor that
    // silently collapses hybrid onto one space fails the bench, not just
    // the equivalence tests. `space/{host,device,hybrid}` rows feed the
    // per-runner perf baseline.
    let hyb_nw = if quick { 2 } else { 4 };
    let mut table_sp = Table::new(&["space", "zc/s", "vs host"]);
    println!("\nExecution-space comparison (uniform, 1 rank, pack_size 2, sched=stealing, w={hyb_nw}):");
    let mut host_zc = 0.0f64;
    for space in ["host", "device", "hybrid"] {
        let mut ovs = vec![
            format!("parthenon/exec/space={space}"),
            "parthenon/exec/sched=stealing".to_string(),
            format!("parthenon/exec/nworkers={hyb_nw}"),
            "parthenon/exec/pack_size=2".to_string(),
        ];
        if space == "hybrid" {
            ovs.push("parthenon/exec/hybrid_split=0.5".to_string());
        }
        let ov_refs: Vec<&str> = ovs.iter().map(|s| s.as_str()).collect();
        let run = measure(&dev_deck, &ov_refs, 1, 2, meas.max(2));
        if space == "host" {
            host_zc = run.zcps;
        }
        if space == "hybrid" {
            eprintln!("  hybrid counters: {:?}", run.hybrid);
            assert!(
                run.hybrid.packs_host > 0 && run.hybrid.packs_device > 0,
                "hybrid perf lane must execute packs on BOTH spaces: {:?}",
                run.hybrid
            );
        } else {
            assert!(
                run.hybrid.is_untouched(),
                "single-space {space} run must leave HybridStats untouched: {:?}",
                run.hybrid
            );
        }
        table_sp.row(vec![
            space.to_string(),
            fmt_zcps(run.zcps),
            format!("{:.2}x", run.zcps / host_zc.max(1e-30)),
        ]);
        samples.push(Sample {
            label: format!("space/{space}"),
            secs: vec![run.wall / run.cycles as f64],
            work: run.zcps * run.wall / run.cycles as f64,
        });
        eprintln!("  space {space}: {} zc/s", fmt_zcps(run.zcps));
    }
    table_sp.print();

    // -- device-AMR perf lane: execution spaces on the MULTILEVEL deck -------
    // Same static-refinement mesh as the strong-scaling sweep above, so the
    // general-mode Device path (per-block launches, restrict/prolong ghost
    // segments, flux correction across the level seam) is what gets timed.
    // The hybrid row forces a 50/50 split for true co-execution, with the
    // HybridStats counters asserted live. `mlspace/{host,device,hybrid}`
    // rows feed the per-runner perf baseline: a regression on the device
    // multilevel path fails CI.
    let mut table_ml = Table::new(&["space", "zc/s", "vs host"]);
    println!("\nExecution-space comparison (multilevel, 1 rank, pack_size 2, sched=stealing, w={hyb_nw}):");
    let mut ml_host_zc = 0.0f64;
    for space in ["host", "device", "hybrid"] {
        let mut ovs = vec![
            format!("parthenon/exec/space={space}"),
            "parthenon/exec/sched=stealing".to_string(),
            format!("parthenon/exec/nworkers={hyb_nw}"),
            "parthenon/exec/pack_size=2".to_string(),
        ];
        if space == "hybrid" {
            ovs.push("parthenon/exec/hybrid_split=0.5".to_string());
        }
        let ov_refs: Vec<&str> = ovs.iter().map(|s| s.as_str()).collect();
        let run = measure(&deck, &ov_refs, 1, 2, meas.max(2));
        if space == "host" {
            ml_host_zc = run.zcps;
        }
        if space == "hybrid" {
            eprintln!("  mlspace hybrid counters: {:?}", run.hybrid);
            assert!(
                run.hybrid.packs_host > 0 && run.hybrid.packs_device > 0,
                "multilevel hybrid perf lane must execute packs on BOTH spaces: {:?}",
                run.hybrid
            );
        } else {
            assert!(
                run.hybrid.is_untouched(),
                "single-space multilevel {space} run must leave HybridStats untouched: {:?}",
                run.hybrid
            );
        }
        table_ml.row(vec![
            space.to_string(),
            fmt_zcps(run.zcps),
            format!("{:.2}x", run.zcps / ml_host_zc.max(1e-30)),
        ]);
        samples.push(Sample {
            label: format!("mlspace/{space}"),
            secs: vec![run.wall / run.cycles as f64],
            work: run.zcps * run.wall / run.cycles as f64,
        });
        eprintln!("  mlspace {space}: {} zc/s", fmt_zcps(run.zcps));
    }
    table_ml.print();

    write_results(
        "fig11_multilevel_scaling",
        &samples,
        vec![("quick", quick.into()), ("root", (root as i64).into())],
    );
}
