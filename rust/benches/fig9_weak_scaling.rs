//! FIG. 9 — Weak scaling on uniform grids.
//!
//! Paper: zone-cycles/s/node and parallel efficiency from 1 to 9216
//! Frontier nodes (92% at full machine), fixed work per device.
//!
//! Here: fixed work per rank-thread, ranks swept 1 -> 64 on ONE machine
//! (this testbed time-shares its cores, so ideal scaling is constant TOTAL
//! throughput; efficiency below measures the framework's communication +
//! synchronization overhead growth with rank count — the quantity the
//! paper's efficiency curve isolates once per-node compute is pinned).
//! Both execution spaces are swept on the default tree-collective path,
//! whose O(log P) dt reduction is what makes the 64-rank point tractable.

use parthenon::driver::bench::{deck_3d_xyz, measure};
use parthenon::util::benchkit::{fmt_zcps, quick_mode, write_results, Sample, Table};

fn main() {
    let quick = quick_mode();
    let meas = if quick { 1 } else { 3 };
    let ranks_list: &[usize] = &[1, 4, 16, 64];
    let per_rank = if quick { 16usize } else { 32usize };

    println!("== Fig 9: weak scaling, {per_rank}^3 zones/rank, 1..64 ranks ==\n");
    let mut samples = Vec::new();
    let mut table = Table::new(&[
        "ranks", "host zc/s", "host eff", "device zc/s", "device eff",
    ]);

    let mut base: [f64; 2] = [0.0, 0.0];
    for &r in ranks_list {
        // extend the mesh along x: r blocks of per_rank^3
        let deck = deck_3d_xyz([per_rank * r, per_rank, per_rank], per_rank);
        let host = measure(&deck, &[], r, 1, meas);
        let dev = measure(
            &deck,
            &[
                "parthenon/exec/space=device",
                "parthenon/exec/strategy=perpack",
                "parthenon/exec/pack_size=16",
            ],
            r,
            1,
            meas,
        );
        if r == ranks_list[0] {
            base = [host.zcps, dev.zcps];
        }
        // ideal on a time-shared machine: total throughput constant
        let eff_h = host.zcps / base[0];
        let eff_d = dev.zcps / base[1];
        table.row(vec![
            r.to_string(),
            fmt_zcps(host.zcps),
            format!("{:.2}", eff_h),
            fmt_zcps(dev.zcps),
            format!("{:.2}", eff_d),
        ]);
        for (name, run) in [("host", &host), ("device", &dev)] {
            samples.push(Sample {
                label: format!("weak/{name}/r{r}"),
                secs: vec![run.wall / run.cycles as f64],
                work: run.zcps * run.wall / run.cycles as f64,
            });
        }
        eprintln!("  ranks {r}: host {} dev {}", fmt_zcps(host.zcps), fmt_zcps(dev.zcps));
    }
    println!();
    table.print();
    println!(
        "\n(time-shared testbed: ideal = flat total throughput; eff < 1 is\n\
         the framework's communication/sync overhead — see DESIGN.md)"
    );
    write_results("fig9_weak_scaling", &samples, vec![("quick", quick.into())]);
}
