//! FIG. 8 — Overdecomposition overhead vs buffer/block packing strategy.
//!
//! Paper: fixed 256^3 (GPU) / 128^3 (CPU) mesh, block size swept down to
//! 16^3 / 8^3; GPU per-buffer kernels degrade ~82x, buffer packing -> ~13x,
//! +block packing -> ~3.5x, CPU flat ~3.5x.
//!
//! Here: fixed 64^3 mesh (32^3 quick), blocks 64^3 -> 8^3 (1 -> 512
//! blocks). "Device" = PJRT executables, where one execute() call carries
//! the same fixed launch cost a GPU kernel launch does; "Host" = native
//! Rust (launch-free), the CPU analog. Reported: performance relative to
//! the single-block device run (paper's normalization).

use parthenon::driver::bench::{deck_3d, measure};
use parthenon::util::benchkit::{fmt_zcps, quick_mode, write_results, Sample, Table};

fn main() {
    let quick = quick_mode();
    let mesh = if quick { 32 } else { 64 };
    let blocks: &[usize] = if quick { &[32, 16, 8] } else { &[64, 32, 16, 8] };
    let meas = if quick { 1 } else { 2 };

    println!("== Fig 8: overdecomposition x packing strategy (mesh {mesh}^3) ==\n");
    let mut samples: Vec<Sample> = Vec::new();
    let mut rows: Vec<(String, Vec<f64>, Vec<u64>)> = Vec::new();

    let strategies: &[(&str, &str)] = &[
        ("device/perbuffer (original)", "perbuffer"),
        ("device/perblock (buffer packing)", "perblock"),
        ("device/perpack (+block packing)", "perpack"),
        ("host/native (CPU analog)", "native"),
    ];

    for (label, strat) in strategies {
        let mut zs = Vec::new();
        let mut launches = Vec::new();
        for &bx in blocks {
            // the worst per-buffer configs get very slow; trim cycles there
            let m = if *strat == "perbuffer" && mesh / bx >= 8 { 1 } else { meas };
            let deck = deck_3d(mesh, bx);
            let ovs: Vec<String> = if *strat == "native" {
                vec!["parthenon/exec/space=host".into()]
            } else {
                vec![
                    "parthenon/exec/space=device".into(),
                    format!("parthenon/exec/strategy={strat}"),
                    "parthenon/exec/pack_size=16".into(),
                ]
            };
            let ov_refs: Vec<&str> = ovs.iter().map(|s| s.as_str()).collect();
            let run = measure(&deck, &ov_refs, 1, 1, m);
            eprintln!(
                "  {label:35} block {bx:3}^3 ({:4} blocks): {} zc/s, {} launches",
                run.nblocks,
                fmt_zcps(run.zcps),
                run.launches
            );
            zs.push(run.zcps);
            launches.push(run.launches);
            samples.push(Sample {
                label: format!("{label}/b{bx}"),
                secs: vec![run.wall / run.cycles as f64],
                work: run.zcps * run.wall / run.cycles as f64,
            });
        }
        rows.push((label.to_string(), zs, launches));
    }

    // normalize to the single-block device (perpack) run, like the paper
    let base = rows
        .iter()
        .find(|(l, _, _)| l.contains("perpack"))
        .map(|(_, z, _)| z[0])
        .unwrap_or(1.0);

    println!("\nRelative performance (1.0 = single-block device run):");
    let mut headers = vec!["strategy".to_string()];
    for &bx in blocks {
        headers.push(format!("{bx}^3"));
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);
    for (label, zs, _) in &rows {
        let mut cells = vec![label.clone()];
        for z in zs {
            cells.push(format!("{:.3}", z / base));
        }
        table.row(cells);
    }
    table.print();

    println!("\nOverhead factor at max overdecomposition (paper: 82x / 13x / 3.5x / 3.5x):");
    for (label, zs, _) in &rows {
        let overhead = zs[0].max(base) / zs[zs.len() - 1];
        println!("  {label:38} {overhead:7.1}x");
    }

    write_results("fig8_overdecomposition", &samples, vec![
        ("mesh", (mesh as i64).into()),
        ("quick", quick.into()),
    ]);
}
