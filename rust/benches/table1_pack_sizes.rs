//! TABLE 1 — Pack sizes, ranks per device, and overdecomposition.
//!
//! Paper: performance per node on 16 Summit nodes for a uniform and a
//! multilevel mesh, varying blocks/device, MeshBlockPacks/rank and ranks/
//! GPU (via MPS). Packing and more ranks per device each buy ~2x on the
//! multilevel mesh.
//!
//! Here (single machine, DESIGN.md substitution table): ranks = rank
//! threads sharing the machine, pack size = fused-artifact batch, blocks/
//! device swept via block size. The multilevel mesh runs on the Host path
//! (Device = uniform periodic only; its column reports native packing,
//! which — like the paper's CPU rows — is insensitive to pack size).

use parthenon::driver::bench::{deck_3d, deck_multilevel, measure};
use parthenon::util::benchkit::{fmt_zcps, quick_mode, write_results, Sample, Table};

fn main() {
    let quick = quick_mode();
    let mesh = if quick { 32 } else { 64 };
    let meas = if quick { 1 } else { 2 };

    println!("== Table 1: pack size x ranks (uniform {mesh}^3 device; multilevel host) ==\n");
    let mut samples = Vec::new();

    // -- uniform mesh on the Device path -------------------------------------
    let block_sizes: &[usize] = if quick { &[16] } else { &[32, 16] };
    let pack_sizes: &[usize] = &[16, 4, 1];
    let ranks_list: &[usize] = &[1, 2, 4];

    let mut table = Table::new(&["blocks/dev", "packs", "ranks=1", "ranks=2", "ranks=4"]);
    for &bx in block_sizes {
        for &ps in pack_sizes {
            let mut cells = vec![
                format!("{} ({bx}^3)", (mesh / bx).pow(3)),
                if ps == 1 { "B".into() } else { format!("nb{ps}") },
            ];
            for &r in ranks_list {
                let deck = deck_3d(mesh, bx);
                let ovs = vec![
                    "parthenon/exec/space=device".to_string(),
                    "parthenon/exec/strategy=perpack".to_string(),
                    format!("parthenon/exec/pack_size={ps}"),
                ];
                let ov_refs: Vec<&str> = ovs.iter().map(|s| s.as_str()).collect();
                let run = measure(&deck, &ov_refs, r, 1, meas);
                cells.push(fmt_zcps(run.zcps));
                samples.push(Sample {
                    label: format!("uniform/b{bx}/ps{ps}/r{r}"),
                    secs: vec![run.wall / run.cycles as f64],
                    work: run.zcps * run.wall / run.cycles as f64,
                });
                eprintln!(
                    "  uniform b{bx} ps{ps} ranks{r}: {} zc/s ({} launches)",
                    fmt_zcps(run.zcps),
                    run.launches
                );
            }
            table.row(cells);
        }
    }
    println!("\nUniform mesh (device, zone-cycles/s):");
    table.print();

    // -- uniform mesh on the Host path: pack_size sweep ------------------------
    // Packs are the unit of work for the host worker pool, so pack_size now
    // shapes Host-path scheduling too (tentpole acceptance: the sweep must
    // affect the Host path, and any parallel config must beat the seed's
    // sequential per-block loop).
    let host_bx = if quick { 8 } else { 16 }; // >= 64 blocks
    let mut table_h = Table::new(&["pack_size", "ranks=1", "ranks=2"]);
    for &ps in pack_sizes {
        let mut cells = vec![format!("ps={ps}")];
        for &r in &[1usize, 2] {
            let deck = deck_3d(mesh, host_bx);
            let ov = format!("parthenon/exec/pack_size={ps}");
            let run = measure(&deck, &[&ov], r, 1, meas);
            cells.push(fmt_zcps(run.zcps));
            samples.push(Sample {
                label: format!("host/b{host_bx}/ps{ps}/r{r}"),
                secs: vec![run.wall / run.cycles as f64],
                work: run.zcps * run.wall / run.cycles as f64,
            });
            eprintln!(
                "  host b{host_bx} ps{ps} ranks{r}: {} zc/s ({} blocks)",
                fmt_zcps(run.zcps),
                run.nblocks
            );
        }
        table_h.row(cells);
    }
    println!("\nUniform mesh (host path, pack-parallel workers, zone-cycles/s):");
    table_h.print();

    // -- host worker sweep: static vs stealing at fixed pack size --------------
    // The tentpole lever: with uneven pack tails, stealing should close the
    // gap as workers grow (JSON labels host_sched/{static,steal}/w{n}).
    let mut table_w = Table::new(&["nworkers", "static", "stealing"]);
    for &nw in &[1usize, 2, 4] {
        let mut cells = vec![format!("w={nw}")];
        for sched in ["static", "stealing"] {
            let deck = deck_3d(mesh, host_bx);
            let ovs = [
                format!("parthenon/exec/sched={sched}"),
                format!("parthenon/exec/nworkers={nw}"),
                "parthenon/exec/pack_size=4".to_string(),
            ];
            let ov_refs: Vec<&str> = ovs.iter().map(|s| s.as_str()).collect();
            let run = measure(&deck, &ov_refs, 1, 1, meas);
            cells.push(fmt_zcps(run.zcps));
            let label = if sched == "static" { "static" } else { "steal" };
            samples.push(Sample {
                label: format!("host_sched/{label}/w{nw}"),
                secs: vec![run.wall / run.cycles as f64],
                work: run.zcps * run.wall / run.cycles as f64,
            });
            eprintln!("  host sched {sched} w{nw}: {} zc/s", fmt_zcps(run.zcps));
        }
        table_w.row(cells);
    }
    println!("\nUniform mesh (host path, worker sweep, zone-cycles/s):");
    table_w.print();

    // -- multilevel mesh on the Host path -------------------------------------
    let mut table2 = Table::new(&["mesh", "ranks=1", "ranks=2", "ranks=4"]);
    let mut cells = vec!["multilevel (host)".to_string()];
    for &r in ranks_list {
        let deck = deck_multilevel(if quick { 16 } else { 32 }, 8, 1);
        let run = measure(&deck, &[], r, 1, meas);
        cells.push(fmt_zcps(run.zcps));
        samples.push(Sample {
            label: format!("multilevel/r{r}"),
            secs: vec![run.wall / run.cycles as f64],
            work: run.zcps * run.wall / run.cycles as f64,
        });
        eprintln!("  multilevel ranks{r}: {} zc/s ({} blocks)", fmt_zcps(run.zcps), run.nblocks);
    }
    table2.row(cells);
    println!("\nMultilevel mesh (host path; Device requires uniform — see DESIGN.md):");
    table2.print();

    write_results("table1_pack_sizes", &samples, vec![("quick", quick.into())]);
}
