//! Micro-benchmarks of the communication substrate: simmpi point-to-point
//! latency/throughput, collectives, buffer pack/unpack rates (native vs
//! device executables), and raw executable-launch overhead — the constants
//! behind Fig 8's regimes.

use parthenon::bvals::bufspec;
use parthenon::comm::{CollMode, Payload, ReduceOp, World};
use parthenon::mesh::IndexShape;
use parthenon::runtime::{default_artifact_dir, ArtifactKey, Runtime, ScalArgs};
use parthenon::util::benchkit::{quick_mode, run, write_results, Table};
use parthenon::NHYDRO;

fn main() {
    let quick = quick_mode();
    let reps = if quick { 20 } else { 200 };
    let mut samples = Vec::new();
    let mut table = Table::new(&["micro-benchmark", "median", "throughput"]);

    // -- simmpi ping-pong latency ---------------------------------------------
    {
        let n = if quick { 200 } else { 2000 };
        let s = run("pingpong", n as f64, 2, 5, || {
            World::launch(2, move |rank, world| {
                let comm = world.comm(rank, 1);
                for i in 0..n {
                    if rank == 0 {
                        comm.isend(1, i, Payload::F32(vec![1.0; 16]));
                        let _ = comm.recv(1, i).unwrap();
                    } else {
                        let _ = comm.recv(0, i).unwrap();
                        comm.isend(0, i, Payload::F32(vec![1.0; 16]));
                    }
                }
            });
        });
        table.row(vec![
            "pingpong (64B) round trip".into(),
            format!("{:.2} us", s.median_secs() / n as f64 * 1e6),
            format!("{:.0}/s", s.throughput()),
        ]);
        samples.push(s);
    }

    // -- allreduce ---------------------------------------------------------------
    {
        let n = if quick { 100 } else { 1000 };
        let s = run("allreduce4", n as f64, 2, 5, || {
            World::launch(4, move |rank, world| {
                let comm = world.comm(rank, 1);
                for _ in 0..n {
                    let _ = comm.allreduce(rank as f64, ReduceOp::Min);
                }
            });
        });
        table.row(vec![
            "allreduce (4 ranks)".into(),
            format!("{:.2} us", s.median_secs() / n as f64 * 1e6),
            format!("{:.0}/s", s.throughput()),
        ]);
        samples.push(s);
    }

    // -- collective algorithm sweep: flat (O(P) serialized) vs tree (O(log P)) --
    {
        let n = if quick { 50 } else { 200 };
        for (mode, name) in [(CollMode::Flat, "flat"), (CollMode::Tree, "tree")] {
            for p in [4usize, 16, 64] {
                let label = format!("coll/{name}/r{p}");
                let s = run(&label, n as f64, 2, 5, || {
                    World::launch(p, move |rank, world| {
                        let comm = world.comm(rank, 1).with_coll(mode);
                        for _ in 0..n {
                            let _ = comm.allreduce(rank as f64, ReduceOp::Min);
                        }
                    });
                });
                table.row(vec![
                    format!("allreduce {name} ({p} ranks)"),
                    format!("{:.2} us", s.median_secs() / n as f64 * 1e6),
                    format!("{:.0}/s", s.throughput()),
                ]);
                samples.push(s);
            }
        }
    }

    // -- native pack/unpack rate ---------------------------------------------
    {
        let shape = IndexShape::new(3, [16, 16, 16]);
        let nelem = NHYDRO * shape.ncells_total();
        let buflen = bufspec::buflen(&shape, NHYDRO);
        let arr: Vec<f32> = (0..nelem).map(|i| i as f32).collect();
        let mut bufs = vec![0.0f32; buflen];
        let s = run("native_pack", (reps * buflen) as f64, 3, 7, || {
            for _ in 0..reps {
                bufspec::pack_all(&arr, &shape, NHYDRO, &mut bufs);
            }
        });
        table.row(vec![
            "native pack_all (16^3 block)".into(),
            format!("{:.2} us", s.median_secs() / reps as f64 * 1e6),
            format!("{:.2} GB/s", s.throughput() * 4.0 / 1e9),
        ]);
        samples.push(s);
        let mut arr2 = arr.clone();
        let s = run("native_unpack", (reps * buflen) as f64, 3, 7, || {
            for _ in 0..reps {
                bufspec::unpack_all(&mut arr2, &shape, NHYDRO, &bufs);
            }
        });
        table.row(vec![
            "native unpack_all (16^3 block)".into(),
            format!("{:.2} us", s.median_secs() / reps as f64 * 1e6),
            format!("{:.2} GB/s", s.throughput() * 4.0 / 1e9),
        ]);
        samples.push(s);
    }

    // -- executable-launch overhead (THE Fig-8 constant) -----------------------
    if default_artifact_dir().join("manifest.json").exists() {
        let rt = Runtime::new(default_artifact_dir()).unwrap();
        let key = ArtifactKey::new("pack1", 3, [16, 16, 16], 1).with_nbr(0);
        let nelem = Runtime::block_elems(&key);
        let u = vec![1.0f32; nelem];
        rt.pack1(&key, &u).unwrap(); // compile outside the timer
        let n = if quick { 50 } else { 500 };
        let s = run("launch_overhead", n as f64, 1, 5, || {
            for _ in 0..n {
                let _ = rt.pack1(&key, &u).unwrap();
            }
        });
        table.row(vec![
            "device launch (tiny pack1 kernel)".into(),
            format!("{:.1} us", s.median_secs() / n as f64 * 1e6),
            format!("{:.0}/s", s.throughput()),
        ]);
        samples.push(s);

        // and a full fused launch for contrast
        let key = ArtifactKey::new("fused", 3, [16, 16, 16], 1);
        let buflen = Runtime::buflen(&key);
        let mut uu = vec![1.0f32; nelem];
        for c in 0..nelem / NHYDRO {
            uu[c] = 1.0;
            uu[4 * (nelem / NHYDRO) + c] = 2.5;
        }
        let bufs_in = vec![1.0f32; buflen];
        let mut bufs_out = vec![0.0f32; buflen];
        let scal = ScalArgs {
            g0: 0.5,
            g1: 0.5,
            beta: 0.5,
            dt: 1e-3,
            dx: [0.1; 3],
            gamma: 1.4,
        };
        let mut u0 = uu.clone();
        rt.fused(&key, &mut u0, &uu, &bufs_in, scal, &mut bufs_out).unwrap();
        let n2 = if quick { 20 } else { 100 };
        let s = run("fused_launch", n2 as f64, 1, 5, || {
            let mut uc = uu.clone();
            for _ in 0..n2 {
                let _ = rt.fused(&key, &mut uc, &uu, &bufs_in, scal, &mut bufs_out).unwrap();
            }
        });
        table.row(vec![
            "device launch (fused 16^3 stage)".into(),
            format!("{:.1} us", s.median_secs() / n2 as f64 * 1e6),
            format!("{:.0}/s", s.throughput()),
        ]);
        samples.push(s);
    } else {
        eprintln!("(artifacts not built; skipping launch-overhead rows)");
    }

    println!();
    table.print();
    write_results("micro_comm", &samples, vec![("quick", quick.into())]);
}
