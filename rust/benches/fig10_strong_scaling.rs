//! FIG. 10 — Strong scaling on uniform grids.
//!
//! Paper: fixed ~1024^3 mesh, node count swept 32x; GPU efficiency drops to
//! ~35-67% as per-device work shrinks, CPU stays higher.
//!
//! Here: a fixed 64-block mesh, ranks swept 1 -> 64 so blocks/rank shrinks
//! 64 -> 1. On the time-shared testbed ideal is constant total throughput;
//! the measured decline is the growing communication + synchronization
//! share as per-rank work shrinks — the paper's strong-scaling efficiency
//! once per-node compute is pinned. Runs on the default tree-collective
//! path (O(log P) dt reduction).

use parthenon::driver::bench::{deck_3d, measure};
use parthenon::util::benchkit::{fmt_zcps, quick_mode, write_results, Sample, Table};

fn main() {
    let quick = quick_mode();
    let meas = if quick { 1 } else { 3 };
    // 64 blocks of 16^3 — the 16^3 shape is in every artifact manifest
    // (quick and full), and 64 blocks gives the 64-rank point one block
    // per rank.
    let mesh = 64;
    let bx = 16;
    let nblocks = (mesh / bx) * (mesh / bx) * (mesh / bx);
    let ranks_list: &[usize] = &[1, 4, 16, 64];

    println!("== Fig 10: strong scaling, fixed {mesh}^3 mesh ({nblocks} blocks) ==\n");
    let mut samples = Vec::new();
    let mut table = Table::new(&[
        "ranks", "blocks/rank", "host zc/s", "host eff", "device zc/s", "device eff",
    ]);

    let deck = deck_3d(mesh, bx);
    let mut base = [0.0f64, 0.0];
    for &r in ranks_list {
        let host = measure(&deck, &[], r, 1, meas);
        let dev = measure(
            &deck,
            &[
                "parthenon/exec/space=device",
                "parthenon/exec/strategy=perpack",
                "parthenon/exec/pack_size=16",
            ],
            r,
            1,
            meas,
        );
        if r == ranks_list[0] {
            base = [host.zcps, dev.zcps];
        }
        table.row(vec![
            r.to_string(),
            format!("{}", nblocks / r),
            fmt_zcps(host.zcps),
            format!("{:.2}", host.zcps / base[0]),
            fmt_zcps(dev.zcps),
            format!("{:.2}", dev.zcps / base[1]),
        ]);
        for (name, run) in [("host", &host), ("device", &dev)] {
            samples.push(Sample {
                label: format!("strong/{name}/r{r}"),
                secs: vec![run.wall / run.cycles as f64],
                work: run.zcps * run.wall / run.cycles as f64,
            });
        }
        eprintln!("  ranks {r}: host {} dev {}", fmt_zcps(host.zcps), fmt_zcps(dev.zcps));
    }
    println!();
    table.print();
    write_results("fig10_strong_scaling", &samples, vec![("quick", quick.into())]);
}
