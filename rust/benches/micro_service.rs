//! Service-lane micro-benchmark: many small concurrent simulations in one
//! process (the regime where per-launch overhead, not FLOPs, bounds
//! throughput — the cross-tenant generalization of the paper's pack-size
//! amortization, Sec. 3.6/Fig. 8). Three rows over the SAME tenant fleet:
//! one-at-a-time sequential runs, the service engine with cross-sim pack
//! batching off, and with batching on — each reporting aggregate
//! zone-cycles/s and the p99 per-cycle latency.

use parthenon::config::ParameterInput;
use parthenon::driver::{EvolutionDriver, SimBuilder};
use parthenon::service::{Engine, EngineConfig};
use parthenon::util::benchkit::{quick_mode, write_results, Sample, Table};
use parthenon::util::stealing::StealPolicy;

/// One tiny device tenant: 2 packs of 2 blocks each, so a 64-tenant fleet
/// is 128 same-key launches per stage for batching to fuse.
const NX: usize = 16;

fn tenant_pin() -> ParameterInput {
    let deck = format!(
        "<parthenon/job>\nproblem = kh\nquiet = true\n\n\
         <parthenon/mesh>\nnx1 = {NX}\nnx2 = {NX}\n\n\
         <parthenon/meshblock>\nnx1 = 8\nnx2 = 8\n\n\
         <parthenon/time>\ntlim = 100.0\nnlim = -1\n\n\
         <parthenon/exec>\nspace = device\nstrategy = perpack\npack_size = 2\n\n\
         <hydro>\ngamma = 1.4\ncfl = 0.3\n"
    );
    ParameterInput::from_str(&deck).unwrap()
}

fn p99_ms(lat: &mut Vec<f64>) -> f64 {
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((lat.len() as f64 * 0.99).ceil() as usize).saturating_sub(1);
    lat.get(idx).copied().unwrap_or(0.0) * 1e3
}

/// Sequential oracle: each tenant steps to completion alone. A "cycle" is
/// one tenant advancing once (the fleet needs nsims of them per sweep).
fn bench_sequential(nsims: usize, cycles: usize, reps: usize) -> (Sample, f64) {
    let mut secs = Vec::new();
    let mut lat = Vec::new();
    for rep in 0..reps + 1 {
        let mut sims: Vec<_> = (0..nsims)
            .map(|_| SimBuilder::new(tenant_pin()).build().unwrap())
            .collect();
        let t0 = std::time::Instant::now();
        for _ in 0..cycles {
            for sim in sims.iter_mut() {
                let tc = std::time::Instant::now();
                sim.step().unwrap();
                if rep > 0 {
                    lat.push(tc.elapsed().as_secs_f64());
                }
            }
        }
        if rep > 0 {
            secs.push(t0.elapsed().as_secs_f64());
        }
    }
    let work = (NX * NX * nsims * cycles) as f64;
    (Sample { label: "sequential".into(), secs, work }, p99_ms(&mut lat))
}

/// The service engine: all tenants live at once, one merged region per
/// cycle. A "cycle" is one engine step advancing the WHOLE fleet (its
/// latency is the fleet-wide cycle time).
fn bench_service(
    nsims: usize,
    cycles: usize,
    reps: usize,
    batching: bool,
) -> (Sample, f64, parthenon::metrics::ServiceStats) {
    let label = if batching { "service+batch" } else { "service" };
    let mut secs = Vec::new();
    let mut lat = Vec::new();
    let mut stats = parthenon::metrics::ServiceStats::default();
    for rep in 0..reps + 1 {
        let cfg = EngineConfig {
            nworkers: 0, // auto, like a solo run
            sched: StealPolicy::Heaviest,
            multiplex: true,
            batching,
            artifact_dir: None,
        };
        let mut engine = Engine::new(cfg).unwrap();
        for _ in 0..nsims {
            engine.add_session(tenant_pin()).unwrap();
        }
        let t0 = std::time::Instant::now();
        for _ in 0..cycles {
            let tc = std::time::Instant::now();
            engine.step().unwrap();
            if rep > 0 {
                lat.push(tc.elapsed().as_secs_f64());
            }
        }
        if rep > 0 {
            secs.push(t0.elapsed().as_secs_f64());
        }
        stats = engine.stats();
    }
    let work = (NX * NX * nsims * cycles) as f64;
    (Sample { label: label.into(), secs, work }, p99_ms(&mut lat), stats)
}

fn main() {
    let quick = quick_mode();
    let (nsims, cycles, reps) = if quick { (8, 4, 2) } else { (64, 8, 3) };

    let mut samples = Vec::new();
    let mut table = Table::new(&["service lane", "median", "zcps", "p99 cycle"]);

    let (s, p99) = bench_sequential(nsims, cycles, reps);
    table.row(vec![
        format!("{nsims} sims, one at a time"),
        format!("{:.1} ms", s.median_secs() * 1e3),
        format!("{:.3e}", s.throughput()),
        format!("{p99:.2} ms/sim-cycle"),
    ]);
    let p99_seq = p99;
    samples.push(s);

    let (s, p99_nb, stats_nb) = bench_service(nsims, cycles, reps, false);
    table.row(vec![
        format!("{nsims} concurrent (no batching)"),
        format!("{:.1} ms", s.median_secs() * 1e3),
        format!("{:.3e}", s.throughput()),
        format!("{p99_nb:.2} ms/fleet-cycle"),
    ]);
    samples.push(s);

    let (s, p99_b, stats_b) = bench_service(nsims, cycles, reps, true);
    table.row(vec![
        format!("{nsims} concurrent (batched)"),
        format!("{:.1} ms", s.median_secs() * 1e3),
        format!("{:.3e}", s.throughput()),
        format!("{p99_b:.2} ms/fleet-cycle"),
    ]);
    samples.push(s);

    println!();
    table.print();
    println!(
        "batched: {} fused launches saved {} solo launches; {} cross-sim steals",
        stats_b.batched_launches, stats_b.launches_saved, stats_b.cross_sim_steals
    );
    assert_eq!(
        stats_nb.batched_launches, 0,
        "batching off must never fuse launches"
    );
    write_results(
        "micro_service",
        &samples,
        vec![
            ("quick", quick.into()),
            ("nsims", nsims.into()),
            ("p99_ms_sequential", p99_seq.into()),
            ("p99_ms_service", p99_nb.into()),
            ("p99_ms_service_batched", p99_b.into()),
            ("batched_launches", (stats_b.batched_launches as i64).into()),
            ("launches_saved", (stats_b.launches_saved as i64).into()),
            ("cross_sim_steals", (stats_b.cross_sim_steals as i64).into()),
        ],
    );
}
