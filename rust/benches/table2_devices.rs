//! TABLE 2 — On-node performance portability.
//!
//! Paper: zone-cycles/s of PARTHENON-HYDRO on MI250X/A100/V100/MI100 GPUs
//! and EPYC/Xeon/Power9/A64FX CPUs — one code, many devices.
//!
//! This testbed has exactly one device (x86 CPU), so per the DESIGN.md
//! substitution table the rows become *execution-space/backend variants*
//! of the same single source: the device path through the XLA executables
//! (fused jnp graph, per-block jnp, per-block Pallas-lowered kernel) and
//! the native Rust backend at several rank counts. The portability claim
//! reproduced is "one physics definition, N backends, same answers"
//! (pinned by rust/tests/device_equivalence.rs); the throughput column
//! shows each backend's cost on identical work.

use parthenon::driver::bench::{deck_3d, measure};
use parthenon::util::benchkit::{fmt_zcps, quick_mode, write_results, Sample, Table};

fn main() {
    let quick = quick_mode();
    let meas = if quick { 1 } else { 3 };
    let mesh = 32; // 16^3 blocks so the pallas-kernel variants exist

    println!("== Table 2: execution-space variants (mesh {mesh}^3, blocks 16^3) ==\n");

    let variants: Vec<(&str, Vec<String>, usize)> = vec![
        (
            "Device: XLA fused (jnp), pack 8",
            vec![
                "parthenon/exec/space=device".into(),
                "parthenon/exec/strategy=perpack".into(),
                "parthenon/exec/pack_size=8".into(),
            ],
            1,
        ),
        (
            "Device: XLA per-block (jnp)",
            vec![
                "parthenon/exec/space=device".into(),
                "parthenon/exec/strategy=perblock".into(),
            ],
            1,
        ),
        (
            "Device: Pallas kernel (interpret)",
            vec![
                "parthenon/exec/space=device".into(),
                "parthenon/exec/strategy=perblock".into(),
                "parthenon/exec/impl=pallas".into(),
            ],
            1,
        ),
        ("Host: native Rust, 1 rank", vec![], 1),
        ("Host: native Rust, 2 ranks", vec![], 2),
        ("Host: native Rust, 4 ranks", vec![], 4),
    ];

    let mut samples = Vec::new();
    let mut table = Table::new(&["backend variant", "zone-cycles/s", "launches/cycle"]);
    for (label, ovs, ranks) in &variants {
        let deck = deck_3d(mesh, 16);
        let ov_refs: Vec<&str> = ovs.iter().map(|s| s.as_str()).collect();
        let run = measure(&deck, &ov_refs, *ranks, 1, meas);
        table.row(vec![
            label.to_string(),
            fmt_zcps(run.zcps),
            format!("{}", run.launches / run.cycles.max(1)),
        ]);
        samples.push(Sample {
            label: label.to_string(),
            secs: vec![run.wall / run.cycles as f64],
            work: run.zcps * run.wall / run.cycles as f64,
        });
        eprintln!("  {label}: {} zc/s", fmt_zcps(run.zcps));
    }
    println!();
    table.print();
    println!(
        "\nNOTE: Pallas interpret-mode wallclock is NOT a TPU-performance\n\
         proxy (DESIGN.md §Perf L1); the row demonstrates the L1 kernel\n\
         running in the production pipeline with identical numerics."
    );

    write_results("table2_devices", &samples, vec![("quick", quick.into())]);
}
