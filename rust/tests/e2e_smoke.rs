//! End-to-end smoke: multi-rank Device (PJRT) run and Host AMR run of the
//! Kelvin-Helmholtz problem complete, conserve, and report throughput.

mod common;

use parthenon::comm::{ReduceOp, World};
use parthenon::config::ParameterInput;
use parthenon::driver::{EvolutionDriver, HydroSim};

#[test]
fn device_multirank_kh() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let deck = common::input_deck("kh", [64, 64, 1], [32, 32, 1], "");
    World::launch(2, move |rank, world| {
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        pin.apply_override("parthenon/exec/space=device").unwrap();
        pin.apply_override("parthenon/exec/strategy=perpack").unwrap();
        let mut sim = HydroSim::new(pin, rank, world.clone()).unwrap();
        let coll = world.comm(rank, 0);
        let before = coll.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        for _ in 0..10 {
            sim.step().unwrap();
        }
        sim.sync_device_to_blocks().unwrap();
        let after = coll.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        let rel = ((after[0] - before[0]) / before[0]).abs();
        assert!(rel < 1e-5, "device KH mass drift {rel:.2e}");
        assert!(sim.zc.zcps() > 0.0);
        let launches = sim.device.as_ref().unwrap().rt.launches();
        assert!(launches > 0, "device path must actually launch");
    });
}

#[test]
fn host_amr_kh() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    let deck = common::input_deck("kh", [64, 64, 1], [16, 16, 1], "");
    World::launch(2, move |rank, world| {
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        for ov in [
            "parthenon/mesh/refinement=adaptive",
            "parthenon/mesh/numlevel=2",
            "parthenon/mesh/check_refine_interval=4",
            "hydro/refine_criterion=density_gradient",
            "hydro/refine_tol=0.04",
            "hydro/derefine_tol=0.01",
        ] {
            pin.apply_override(ov).unwrap();
        }
        let mut sim = HydroSim::new(pin, rank, world.clone()).unwrap();
        let coll = world.comm(rank, 0);
        let before = coll.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        for _ in 0..24 {
            sim.step().unwrap();
        }
        let after = coll.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        let rel = ((after[0] - before[0]) / before[0]).abs();
        assert!(rel < 1e-4, "host AMR KH mass drift {rel:.2e}");
        assert!(sim.mesh.tree.is_properly_nested());
    });
}
