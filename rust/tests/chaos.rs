//! Chaos suite: the fault-injection comm layer must never change physics.
//!
//! * Fixed-seed fault soak — delay/dup/reorder injection (p = 0.2 each,
//!   alone and combined) over >= 40 cycles must finish bitwise identical
//!   to the fault-free run: the framing layer absorbs every fabric fault.
//! * Corruption is *detected*, never silently absorbed — a corrupt frame
//!   fails its checksum and every rank drains with an error.
//! * A rank killed mid-run recovers from the last durable checkpoint and
//!   finishes bitwise identical to a run that never died.
//! * An induced deadlock resolves via `Error::Timeout` / `Error::Aborted`
//!   on every rank within the watchdog budget — no hangs.

mod common;

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use parthenon::comm::{ReduceOp, World};
use parthenon::config::{Override, ParameterInput};
use parthenon::driver::{run_recoverable, Driver, HydroSim};
use parthenon::error::Error;
use parthenon::metrics::FaultStats;

fn soak_ranks() -> usize {
    // The chaos CI lane runs with PARTHENON_TEST_RANKS=8; local runs keep
    // the default 2 so `cargo test` stays fast.
    common::test_ranks().clamp(2, 8)
}

fn deck() -> String {
    common::input_deck("blast", [32, 32, 1], [8, 8, 1], "")
}

/// Run `deck` to completion on `nranks` ranks and gather the final state
/// (gid-sorted interiors), rank 0's final dt bits, and the fault counters.
fn run_gather(
    deck: &str,
    overrides: Vec<String>,
    nranks: usize,
) -> (Vec<(usize, Vec<f32>)>, u64, FaultStats) {
    let state: Arc<Mutex<Vec<(usize, Vec<f32>)>>> = Arc::new(Mutex::new(Vec::new()));
    let dt_bits = Arc::new(Mutex::new(0u64));
    let deck = deck.to_string();
    let s2 = state.clone();
    let d2 = dt_bits.clone();
    let world = World::launch(nranks, move |rank, world| {
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        for ov in &overrides {
            pin.apply_override(ov).unwrap();
        }
        let mut sim = HydroSim::new(pin, rank, world).unwrap();
        sim.execute().unwrap();
        sim.sync_device_to_blocks().unwrap();
        let mut blocks = common::cons_by_gid(&sim);
        s2.lock().unwrap().append(&mut blocks);
        if rank == 0 {
            *d2.lock().unwrap() = sim.dt.to_bits();
        }
    });
    let stats = world.fault_stats();
    let mut v = Arc::try_unwrap(state).unwrap().into_inner().unwrap();
    v.sort_by_key(|(g, _)| *g);
    let dt = *dt_bits.lock().unwrap();
    (v, dt, stats)
}

#[test]
fn fault_soak_is_bitwise_identical_to_fault_free() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    let p = soak_ranks();
    let base = vec!["parthenon/time/nlim=40".to_string()];
    let (expect, dt_expect, _) = run_gather(&deck(), base.clone(), p);
    assert!(!expect.is_empty());

    let lanes: &[(&str, &[&str])] = &[
        ("delay", &["parthenon/fault/delay_prob=0.2"]),
        ("dup", &["parthenon/fault/dup_prob=0.2"]),
        ("reorder", &["parthenon/fault/reorder_prob=0.2"]),
        (
            "all",
            &[
                "parthenon/fault/delay_prob=0.2",
                "parthenon/fault/dup_prob=0.2",
                "parthenon/fault/reorder_prob=0.2",
            ],
        ),
    ];
    for (name, faults) in lanes {
        let mut ovr = base.clone();
        ovr.push("parthenon/fault/seed=987654321".to_string());
        ovr.extend(faults.iter().map(|s| s.to_string()));
        let (got, dt_got, stats) = run_gather(&deck(), ovr, p);
        // the lane must actually have injected something
        let injected = stats.delayed + stats.duplicated + stats.reordered;
        assert!(injected > 0, "{name}: no faults injected ({stats:?})");
        if name.contains("dup") || *name == "all" {
            assert!(stats.duplicates_dropped > 0, "{name}: dups never absorbed");
        }
        let diff = common::max_state_diff(&expect, &got);
        assert_eq!(diff, 0.0, "{name}: faulty run diverged from fault-free");
        assert_eq!(dt_expect, dt_got, "{name}: dt bits diverged");
    }
}

#[test]
fn corruption_is_detected_never_absorbed() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    let p = soak_ranks();
    let deck = deck();
    let errs: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let e2 = errs.clone();
    let world = World::launch(p, move |rank, world| {
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        pin.apply_override("parthenon/time/nlim=40").unwrap();
        pin.apply_override("parthenon/fault/seed=24680").unwrap();
        pin.apply_override("parthenon/fault/corrupt_prob=0.2").unwrap();
        let r = (|| -> parthenon::error::Result<()> {
            // corruption can already fire in the construction-time ghost
            // exchange, so `new` itself is under test here
            let mut sim = HydroSim::new(pin, rank, world)?;
            sim.execute()
        })();
        let e = r.expect_err("corrupt frames must never be absorbed as data");
        assert!(
            matches!(
                e,
                Error::CorruptMessage { .. } | Error::Aborted { .. } | Error::Timeout { .. }
            ),
            "rank {rank}: unexpected error {e}"
        );
        e2.lock().unwrap().push(e.to_string());
    });
    let stats = world.fault_stats();
    assert!(stats.corrupted_injected > 0, "{stats:?}");
    assert!(stats.corruption_detected > 0, "{stats:?}");
    assert!(world.aborted(), "detection must post the cooperative abort");
    assert_eq!(errs.lock().unwrap().len(), p, "every rank must observe the failure");
}

#[test]
fn kill_and_recover_is_bitwise_identical() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    let p = soak_ranks();
    let pid = std::process::id();
    let dir_faulty = std::env::temp_dir().join(format!("parthenon_chaos_kill_{pid}"));
    let dir_clean = std::env::temp_dir().join(format!("parthenon_chaos_clean_{pid}"));
    let _ = std::fs::remove_dir_all(&dir_faulty);
    let _ = std::fs::remove_dir_all(&dir_clean);
    let deck = deck();
    let base = |dir: &std::path::Path| -> Vec<Override> {
        vec![
            Override::new("parthenon/time", "nlim", 20),
            Override::new("parthenon/job", "checkpoint_interval", 5),
            Override::new("parthenon/job", "out_dir", dir.to_str().unwrap()),
        ]
    };

    // killed at cycle 12: the durable checkpoint is cycle 10, so recovery
    // replays cycles 11..20 from restored state
    let mut faulty = base(&dir_faulty);
    faulty.push(Override::new("parthenon/fault", "kill_rank", 1));
    faulty.push(Override::new("parthenon/fault", "kill_cycle", 12));
    let rep = run_recoverable(&deck, &faulty, p, 3).unwrap();
    assert_eq!(rep.attempts, 2, "exactly one relaunch: {:?}", rep.failures);
    assert_eq!(rep.restored, 1, "relaunch must restore from the checkpoint");
    assert_eq!(rep.final_cycle, 20);

    // uninterrupted reference
    let rep_clean = run_recoverable(&deck, &base(&dir_clean), p, 0).unwrap();
    assert_eq!(rep_clean.attempts, 1);
    assert_eq!(rep_clean.final_cycle, 20);

    assert_eq!(
        rep.final_time.to_bits(),
        rep_clean.final_time.to_bits(),
        "recovered final time must match bitwise"
    );
    // the cycle-20 checkpoints are full-state dumps: byte-for-byte equality
    // is the strongest statement of recovery fidelity
    let a = std::fs::read(dir_faulty.join("parthenon.chk.pbin")).unwrap();
    let b = std::fs::read(dir_clean.join("parthenon.chk.pbin")).unwrap();
    assert_eq!(a, b, "recovered checkpoint differs from the uninterrupted one");
    let _ = std::fs::remove_dir_all(&dir_faulty);
    let _ = std::fs::remove_dir_all(&dir_clean);
}

#[test]
fn induced_deadlock_escalates_on_every_rank() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    let p = soak_ranks();
    let t0 = Instant::now();
    let errs: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let e2 = errs.clone();
    let world = World::launch(p, move |rank, world| {
        world.set_watchdog(Duration::from_millis(300));
        let comm = world.comm(rank, 9);
        let r = if rank + 1 < p {
            // these ranks enter a collective the last rank never joins
            comm.iallreduce(rank as f64, ReduceOp::Min).into_f64()
        } else {
            // the abstainer just watches for the cooperative abort
            loop {
                if world.aborted() {
                    break Err(world.abort_error(rank));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        };
        let e = r.expect_err("a deadlocked collective must not succeed");
        assert!(
            matches!(e, Error::Timeout { .. } | Error::Aborted { .. }),
            "rank {rank}: unexpected error {e}"
        );
        e2.lock().unwrap().push(e.to_string());
    });
    // every rank escalated well within a few watchdog periods (the test
    // *finishing* is the no-hang statement; the bound keeps it honest)
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "deadlock resolution took {:?}",
        t0.elapsed()
    );
    assert_eq!(errs.lock().unwrap().len(), p);
    let stats = world.fault_stats();
    assert!(stats.timeouts >= 1, "{stats:?}");
    assert!(stats.aborts_posted >= 1, "{stats:?}");
}
