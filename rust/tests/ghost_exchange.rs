//! Ghost-zone exchange exactness on uniform meshes: after one exchange,
//! every ghost cell must equal the (periodically wrapped) global field —
//! across blocks, ranks, faces, edges and corners.

mod common;

use parthenon::bvals;
use parthenon::comm::{tags, World};
use parthenon::config::ParameterInput;
use parthenon::driver::HydroSim;
use parthenon::hydro::CONS;
use parthenon::NGHOST;

/// Deterministic global-cell fingerprint.
fn field(v: usize, gx: i64, gy: i64, gz: i64) -> f32 {
    ((v as i64 * 1_000_003 + gx * 37 + gy * 101 + gz * 733) % 100_000) as f32
}

fn run_case(dim: usize, nranks: usize) {
    let (nx, bx) = match dim {
        1 => ([32, 1, 1], [8, 1, 1]),
        2 => ([16, 16, 1], [8, 8, 1]),
        _ => ([16, 16, 16], [8, 8, 8]),
    };
    let deck = common::input_deck("uniform", nx, bx, "");
    World::launch(nranks, move |rank, world| {
        let pin = ParameterInput::from_str(&deck).unwrap();
        let mut sim = HydroSim::new(pin, rank, world.clone()).unwrap();
        let shape = sim.mesh.cfg.index_shape();
        let n = shape.ncells_total();

        // paint interiors with the global fingerprint
        for b in &mut sim.mesh.blocks {
            let loc = b.loc;
            let arr = b.data.get_mut(CONS).unwrap();
            for v in 0..5 {
                for k in shape.is_(2)..shape.ie(2) {
                    for j in shape.is_(1)..shape.ie(1) {
                        for i in shape.is_(0)..shape.ie(0) {
                            let gx = loc.lx[0] * shape.n[0] as i64 + (i - shape.is_(0)) as i64;
                            let gy = loc.lx[1] * shape.n[1] as i64 + (j - shape.is_(1)) as i64;
                            let gz = loc.lx[2] * shape.n[2] as i64 + (k - shape.is_(2)) as i64;
                            arr.as_mut_slice()[v * n + shape.idx3(k, j, i)] =
                                field(v, gx, gy, gz);
                        }
                    }
                }
            }
        }

        let comm = world.comm(rank, tags::COMM_BVALS_BASE);
        bvals::exchange_blocking(&mut sim.mesh, &comm, CONS, None).unwrap();

        // every cell (ghosts included) must match the wrapped global field
        let tot = [
            (nx[0]) as i64,
            (nx[1]) as i64,
            (nx[2]) as i64,
        ];
        for b in &sim.mesh.blocks {
            let loc = b.loc;
            let arr = b.data.get(CONS).unwrap();
            for v in 0..5 {
                for k in 0..shape.nt(2) {
                    for j in 0..shape.nt(1) {
                        for i in 0..shape.nt(0) {
                            let gx = (loc.lx[0] * shape.n[0] as i64 + i as i64
                                - if dim >= 1 { NGHOST as i64 } else { 0 })
                                .rem_euclid(tot[0]);
                            let gy = (loc.lx[1] * shape.n[1] as i64 + j as i64
                                - if dim >= 2 { NGHOST as i64 } else { 0 })
                                .rem_euclid(tot[1]);
                            let gz = (loc.lx[2] * shape.n[2] as i64 + k as i64
                                - if dim >= 3 { NGHOST as i64 } else { 0 })
                                .rem_euclid(tot[2]);
                            let expect = field(v, gx, gy, gz);
                            let got = arr.as_slice()[v * n + shape.idx3(k, j, i)];
                            assert_eq!(
                                got, expect,
                                "rank {rank} gid {} v{v} ({k},{j},{i})",
                                b.gid
                            );
                        }
                    }
                }
            }
        }
    });
}

#[test]
fn exchange_1d_1rank() {
    run_case(1, 1);
}

#[test]
fn exchange_2d_1rank() {
    run_case(2, 1);
}

#[test]
fn exchange_2d_3ranks() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    run_case(2, 3);
}

#[test]
fn exchange_3d_2ranks() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    run_case(3, 2);
}

#[test]
fn exchange_3d_4ranks() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    run_case(3, 4);
}

#[test]
fn outflow_bc_fills_ghosts() {
    // non-periodic x: ghosts replicate the edge interior value
    let deck = common::input_deck(
        "uniform",
        [16, 16, 1],
        [8, 8, 1],
        "\n<parthenon/mesh_bc_patch>\nx = 1\n",
    );
    let world = World::new(1);
    let mut pin = ParameterInput::from_str(&deck).unwrap();
    pin.set("parthenon/mesh", "ix1_bc", "outflow");
    pin.set("parthenon/mesh", "ox1_bc", "outflow");
    let mut sim = HydroSim::new(pin, 0, world.clone()).unwrap();
    let shape = sim.mesh.cfg.index_shape();
    let n = shape.ncells_total();

    for b in &mut sim.mesh.blocks {
        let arr = b.data.get_mut(CONS).unwrap();
        for j in shape.is_(1)..shape.ie(1) {
            for i in shape.is_(0)..shape.ie(0) {
                arr.as_mut_slice()[shape.idx3(0, j, i)] = (10 + i) as f32;
            }
        }
    }
    let comm = world.comm(0, tags::COMM_BVALS_BASE);
    bvals::exchange_blocking(&mut sim.mesh, &comm, CONS, None).unwrap();

    for b in &sim.mesh.blocks {
        if b.loc.lx[0] != 0 {
            continue;
        }
        let arr = b.data.get(CONS).unwrap();
        for j in shape.is_(1)..shape.ie(1) {
            // x-lo ghosts replicate first interior value (outflow)
            let edge = arr.as_slice()[n * 0 + shape.idx3(0, j, shape.is_(0))];
            for i in 0..NGHOST {
                assert_eq!(arr.as_slice()[shape.idx3(0, j, i)], edge);
            }
        }
    }
}
