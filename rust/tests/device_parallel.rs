//! Worker-parallel Device launches through the shared-state `Runtime`:
//! the fused Device stage drives per-pack task lists on the stealing pool,
//! so results must be BITWISE identical to the phased single-worker oracle
//! for every worker count and steal policy; concurrent launches must
//! compile each artifact exactly once; and the fused dt reduction (the
//! regional cross-list min fold that replaced the post-cycle `local_dt`
//! sweep) must reproduce the phased timestep bit-for-bit on both
//! execution spaces.

mod common;

use parthenon::driver::EvolutionDriver;
use parthenon::runtime::{default_artifact_dir, ArtifactKey, Runtime, ScalArgs};

/// Run `deck` single-rank for `steps`; return (gid -> interior CONS, dt).
fn run_sim(deck: &str, overrides: &[&str], steps: usize) -> (Vec<(usize, Vec<f32>)>, f64) {
    let mut sim = common::single_rank_sim(deck, overrides);
    for _ in 0..steps {
        sim.step().unwrap();
    }
    sim.sync_device_to_blocks().unwrap();
    (common::cons_by_gid(&sim), sim.dt)
}

#[test]
fn device_fused_bitwise_identical_across_workers_and_scheds() {
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // 16 blocks, pack_size 4 -> 4 per-pack task lists to deal and steal.
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let (base, base_dt) = run_sim(
        &deck,
        &[
            "parthenon/exec/space=device",
            "parthenon/exec/overlap=phased",
            "parthenon/exec/sched=static",
            "parthenon/exec/nworkers=1",
            "parthenon/exec/pack_size=4",
        ],
        4,
    );
    for sched in ["static", "stealing"] {
        for nw in [1usize, 2, 4, 8] {
            let ov_sched = format!("parthenon/exec/sched={sched}");
            let ov_nw = format!("parthenon/exec/nworkers={nw}");
            let (got, got_dt) = run_sim(
                &deck,
                &[
                    "parthenon/exec/space=device",
                    "parthenon/exec/overlap=fused",
                    &ov_sched,
                    &ov_nw,
                    "parthenon/exec/pack_size=4",
                ],
                4,
            );
            assert_eq!(
                common::max_state_diff(&base, &got),
                0.0,
                "device fused sched={sched} nworkers={nw} must be bitwise \
                 identical to the phased single-worker oracle"
            );
            assert_eq!(
                got_dt.to_bits(),
                base_dt.to_bits(),
                "fused regional dt reduction (sched={sched} nworkers={nw}) \
                 must reproduce the phased timestep bit-for-bit"
            );
        }
    }
}

#[test]
fn host_fused_dt_reduction_matches_phased_sweep() {
    // Multilevel mesh: uneven per-block dts, flux correction live — the
    // per-pack partial minima + regional fold must still agree with the
    // phased path's whole-rank sweep bit-for-bit.
    let deck = common::input_deck("blast", [16, 16, 1], [4, 4, 1], "");
    let ml = [
        "parthenon/mesh/refinement=static",
        "parthenon/mesh/numlevel=2",
        "parthenon/static_refinement0/level=1",
        "parthenon/static_refinement0/x1min=0.3",
        "parthenon/static_refinement0/x1max=0.7",
        "parthenon/static_refinement0/x2min=0.3",
        "parthenon/static_refinement0/x2max=0.7",
        "parthenon/exec/pack_size=2",
    ];
    let mut base_ov: Vec<&str> = ml.to_vec();
    base_ov.push("parthenon/exec/overlap=phased");
    base_ov.push("parthenon/exec/nworkers=2");
    let (base, base_dt) = run_sim(&deck, &base_ov, 3);
    for nw in [1usize, 4] {
        let ov_nw = format!("parthenon/exec/nworkers={nw}");
        let mut got_ov: Vec<&str> = ml.to_vec();
        got_ov.push("parthenon/exec/overlap=fused");
        got_ov.push(&ov_nw);
        let (got, got_dt) = run_sim(&deck, &got_ov, 3);
        assert_eq!(common::max_state_diff(&base, &got), 0.0);
        assert_eq!(
            got_dt.to_bits(),
            base_dt.to_bits(),
            "host fused dt reduction (nworkers={nw}) must match the phased \
             sweep bit-for-bit"
        );
    }
}

#[test]
fn concurrent_launches_compile_each_artifact_exactly_once() {
    // Many worker threads race cold keys on one shared Runtime: the
    // RwLock'd compile-once map must create each executable exactly once
    // (`num_compiled` fixed) while every launch is still counted.
    let rt = Runtime::new(default_artifact_dir()).unwrap();
    let kst = ArtifactKey::new("stage", 2, [8, 8, 1], 1);
    let kfu = ArtifactKey::new("fused", 2, [8, 8, 1], 2);
    let ne1 = Runtime::block_elems(&kst);
    let bl = Runtime::buflen(&kst);
    let ncell = ne1 / parthenon::NHYDRO;
    let mut u1 = vec![0.0f32; ne1];
    for c in 0..ncell {
        u1[c] = 1.0;
        u1[4 * ncell + c] = 2.5;
    }
    let mut u2 = vec![0.0f32; 2 * ne1];
    u2[..ne1].copy_from_slice(&u1);
    u2[ne1..].copy_from_slice(&u1);
    let bufs_in = vec![1.0f32; 2 * bl];
    let scal = ScalArgs {
        g0: 0.0,
        g1: 1.0,
        beta: 1.0,
        dt: 1e-3,
        dx: [0.1; 3],
        gamma: 1.4,
    };
    let nthreads = 8;
    let per_thread = 8;
    std::thread::scope(|s| {
        for _ in 0..nthreads {
            let (rt, kst, kfu) = (&rt, &kst, &kfu);
            let (u1, u2, bufs_in) = (&u1, &u2, &bufs_in);
            s.spawn(move || {
                let mut out = vec![0.0f32; ne1];
                let mut mine = u2.clone();
                let mut bufs_out = vec![0.0f32; 2 * bl];
                for _ in 0..per_thread {
                    rt.stage(kst, u1, u1, scal, &mut out).unwrap();
                    let u0 = mine.clone();
                    rt.fused(kfu, &mut mine, &u0, bufs_in, scal, &mut bufs_out)
                        .unwrap();
                }
            });
        }
    });
    assert_eq!(
        rt.num_compiled(),
        2,
        "each (kind, shape, pack-size) artifact compiles exactly once \
         under concurrent launches"
    );
    assert_eq!(rt.launches(), (2 * nthreads * per_thread) as u64);
}

#[test]
fn device_run_compiles_one_executable_per_variant() {
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Worker-parallel fused stages over several cycles must not re-prepare
    // executables: the compile count stays at the number of distinct
    // (kind, pack-size) variants the plan actually uses.
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let mut sim = common::single_rank_sim(
        &deck,
        &[
            "parthenon/exec/space=device",
            "parthenon/exec/overlap=fused",
            "parthenon/exec/sched=stealing",
            "parthenon/exec/nworkers=4",
            "parthenon/exec/pack_size=4",
        ],
    );
    for _ in 0..2 {
        sim.step().unwrap();
    }
    let compiled = sim.device.as_ref().unwrap().rt.num_compiled();
    for _ in 0..3 {
        sim.step().unwrap();
    }
    let dev = sim.device.as_ref().unwrap();
    assert_eq!(
        dev.rt.num_compiled(),
        compiled,
        "steady-state cycles must reuse compiled executables"
    );
    assert!(dev.rt.launches() > 0);
}
