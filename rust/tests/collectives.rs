//! Tree-structured collectives vs the flat bulk-synchronous oracle.
//!
//! The `comm::coll` tree path (binomial reduce+broadcast, dissemination
//! barrier) must be BITWISE identical to the flat generation-counted
//! oracle — for the raw ops (Min/Max/Sum, u64, allgather), under
//! multi-threaded contention, and end-to-end through a full simulation
//! where the tree path additionally overlaps the global dt reduction with
//! the fused stage's boundary polls (state AND dt bits must match across
//! schedulers, worker counts and execution spaces).

mod common;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use parthenon::comm::{CollMode, Payload, ReduceOp, World};
use parthenon::config::ParameterInput;
use parthenon::driver::{regrid, EvolutionDriver, HydroSim};

/// Per-rank input values with mixed signs/magnitudes (nothing special
/// about them beyond being awkward for naive summation).
fn rank_value(rank: usize, i: usize) -> f64 {
    let s = if (rank + i) % 2 == 0 { 1.0 } else { -1.0 };
    s * (1.0 + rank as f64 * 0.3125 + i as f64 * 1e-7) * 10f64.powi((i % 5) as i32 - 2)
}

/// Run `iters` allreduces per op on `p` rank-threads under `mode`; return
/// the result bit patterns (identical on every rank, checked).
fn reduce_bits(mode: CollMode, p: usize, iters: usize) -> Vec<u64> {
    let out: Arc<Mutex<Vec<Vec<u64>>>> = Arc::new(Mutex::new(vec![Vec::new(); p]));
    let o2 = out.clone();
    World::launch(p, move |rank, world| {
        let comm = world.comm(rank, 0).with_coll(mode);
        let mut bits = Vec::new();
        for i in 0..iters {
            for op in [ReduceOp::Min, ReduceOp::Max, ReduceOp::Sum] {
                bits.push(comm.allreduce(rank_value(rank, i), op).to_bits());
            }
        }
        o2.lock().unwrap()[rank] = bits;
    });
    let per_rank = Arc::try_unwrap(out).unwrap().into_inner().unwrap();
    for r in 1..p {
        assert_eq!(per_rank[0], per_rank[r], "ranks 0 and {r} disagree");
    }
    per_rank.into_iter().next().unwrap()
}

#[test]
fn tree_matches_flat_bitwise_for_min_max_sum() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    for p in [2usize, 3, 5, 8] {
        let flat = reduce_bits(CollMode::Flat, p, 8);
        let tree = reduce_bits(CollMode::Tree, p, 8);
        assert_eq!(flat, tree, "tree must be bitwise identical to flat at {p} ranks");
    }
}

#[test]
fn tree_sum_is_reproducible_across_runs() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    // Sum is the order-sensitive op: the tree's fixed fold order (own
    // value, then children ascending) must make repeat runs bit-stable.
    let a = reduce_bits(CollMode::Tree, 7, 8);
    let b = reduce_bits(CollMode::Tree, 7, 8);
    assert_eq!(a, b, "tree Sum fold order must be deterministic");
}

#[test]
fn u64_reduction_is_exact_past_f64_mantissa() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    // (1 << 53) + rank: a f64 Sum would round these; the particle
    // quiescence count relies on the integer path being exact.
    for mode in [CollMode::Flat, CollMode::Tree] {
        let p = 4;
        World::launch(p, move |rank, world| {
            let comm = world.comm(rank, 0).with_coll(mode);
            let total = comm.allreduce_u64((1u64 << 53) + rank as u64);
            assert_eq!(total, 4 * (1u64 << 53) + 6, "mode {mode:?}");
            // and the == 0 stop criterion must be trustworthy
            assert_eq!(comm.allreduce_u64(0), 0, "mode {mode:?}");
        });
    }
}

#[test]
fn allgather_u64s_identical_across_modes() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    // The incremental-rebalance subset refresh is built on allgather_u64s
    // with per-rank payload lengths that legitimately differ.
    let p = 5;
    let gather = |mode: CollMode| {
        let out: Arc<Mutex<Vec<Vec<Vec<u64>>>>> = Arc::new(Mutex::new(vec![Vec::new(); p]));
        let o2 = out.clone();
        World::launch(p, move |rank, world| {
            let comm = world.comm(rank, 0).with_coll(mode);
            let mine: Vec<u64> = (0..rank).map(|i| (rank * 100 + i) as u64).collect();
            o2.lock().unwrap()[rank] = comm.allgather_u64s(&mine);
        });
        Arc::try_unwrap(out).unwrap().into_inner().unwrap()
    };
    let flat = gather(CollMode::Flat);
    let tree = gather(CollMode::Tree);
    assert_eq!(flat, tree);
    // rank order, not arrival order
    for (r, blob) in flat[0].iter().enumerate() {
        assert_eq!(blob.len(), r);
        assert!(blob.iter().enumerate().all(|(i, v)| *v == (r * 100 + i) as u64));
    }
}

#[test]
fn mixed_collectives_under_thread_contention() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    // 8 rank-threads hammering interleaved reductions, gathers, barriers
    // and unrelated pt2pt traffic on the same world: the sequence-tagged
    // tree exchanges must never cross-talk with each other or with the
    // pt2pt messages.
    let p = 8usize;
    let iters = 40usize;
    World::launch(p, move |rank, world| {
        let comm = world.comm(rank, 0).with_coll(CollMode::Tree);
        let pt = world.comm(rank, 7);
        for i in 0..iters {
            pt.isend((rank + 1) % p, i as u64, Payload::F32(vec![rank as f32; 3]));
            let s = comm.allreduce((rank + i) as f64, ReduceOp::Sum);
            let expect: f64 = (0..p).map(|r| (r + i) as f64).sum();
            assert_eq!(s, expect, "iter {i}");
            // two overlapping handles drained out of order
            let h1 = comm.iallreduce(rank as f64, ReduceOp::Max);
            let h2 = comm.iallreduce(rank as f64, ReduceOp::Min);
            assert_eq!(h2.into_f64().unwrap(), 0.0);
            assert_eq!(h1.into_f64().unwrap(), (p - 1) as f64);
            let gathered = comm.allgather(vec![rank as u8; rank % 3]);
            for (r, g) in gathered.iter().enumerate() {
                assert_eq!(g.len(), r % 3, "iter {i}");
            }
            comm.barrier();
            let got = pt.recv((rank + p - 1) % p, i as u64).unwrap().into_f32().unwrap();
            assert_eq!(got, vec![((rank + p - 1) % p) as f32; 3]);
        }
    });
}

/// Run `deck` on `nranks` ranks for `steps`; return (gid -> interior CONS,
/// final dt bits — asserted identical across ranks).
fn run_sim_multirank(
    deck: String,
    overrides: Vec<String>,
    nranks: usize,
    steps: usize,
) -> (Vec<(usize, Vec<f32>)>, u64) {
    let results: Arc<Mutex<HashMap<usize, Vec<f32>>>> = Arc::new(Mutex::new(HashMap::new()));
    let dts: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(vec![0; nranks]));
    let r2 = results.clone();
    let d2 = dts.clone();
    World::launch(nranks, move |rank, world| {
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        for ov in &overrides {
            pin.apply_override(ov).unwrap();
        }
        let mut sim = HydroSim::new(pin, rank, world).unwrap();
        for _ in 0..steps {
            sim.step().unwrap();
        }
        sim.sync_device_to_blocks().unwrap();
        d2.lock().unwrap()[rank] = sim.dt.to_bits();
        let mut res = r2.lock().unwrap();
        for (gid, data) in common::cons_by_gid(&sim) {
            res.insert(gid, data);
        }
    });
    let dts = Arc::try_unwrap(dts).unwrap().into_inner().unwrap();
    for r in 1..nranks {
        assert_eq!(
            dts[0], dts[r],
            "ranks 0 and {r} ended with different global dt bits"
        );
    }
    let map = Arc::try_unwrap(results).unwrap().into_inner().unwrap();
    let mut out: Vec<(usize, Vec<f32>)> = map.into_iter().collect();
    out.sort_by_key(|(gid, _)| *gid);
    (out, dts[0])
}

#[test]
fn sim_state_and_dt_bits_identical_tree_vs_flat_host() {
    // Runs at PARTHENON_TEST_RANKS ranks: 1 in the single-rank CI step,
    // 2 in the multi-rank step — the overlapped dt path must be exact in
    // both regimes.
    let nranks = common::test_ranks();
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let (base_state, base_dt) = run_sim_multirank(
        deck.clone(),
        vec![
            "parthenon/comm/coll=flat".into(),
            "parthenon/exec/sched=static".into(),
            "parthenon/exec/nworkers=1".into(),
            "parthenon/exec/pack_size=2".into(),
        ],
        nranks,
        5,
    );
    for sched in ["static", "stealing"] {
        for nw in [1usize, 4] {
            let (state, dt) = run_sim_multirank(
                deck.clone(),
                vec![
                    "parthenon/comm/coll=tree".into(),
                    format!("parthenon/exec/sched={sched}"),
                    format!("parthenon/exec/nworkers={nw}"),
                    "parthenon/exec/pack_size=2".into(),
                ],
                nranks,
                5,
            );
            assert_eq!(
                common::max_state_diff(&base_state, &state),
                0.0,
                "tree state diverged (sched={sched} nworkers={nw})"
            );
            assert_eq!(
                base_dt, dt,
                "overlapped tree dt bits diverged from the blocking flat \
                 oracle (sched={sched} nworkers={nw})"
            );
        }
    }
}

#[test]
fn sim_state_and_dt_bits_identical_tree_vs_flat_device() {
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let nranks = common::test_ranks();
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let dev = |coll: &str, sched: &str, nw: usize| {
        run_sim_multirank(
            deck.clone(),
            vec![
                format!("parthenon/comm/coll={coll}"),
                "parthenon/exec/space=device".into(),
                "parthenon/exec/strategy=perpack".into(),
                format!("parthenon/exec/sched={sched}"),
                format!("parthenon/exec/nworkers={nw}"),
                "parthenon/exec/pack_size=2".into(),
            ],
            nranks,
            4,
        )
    };
    let (base_state, base_dt) = dev("flat", "static", 1);
    for sched in ["static", "stealing"] {
        for nw in [1usize, 4] {
            let (state, dt) = dev("tree", sched, nw);
            assert_eq!(
                common::max_state_diff(&base_state, &state),
                0.0,
                "device tree state diverged (sched={sched} nworkers={nw})"
            );
            assert_eq!(
                base_dt, dt,
                "device overlapped dt bits diverged (sched={sched} nworkers={nw})"
            );
        }
    }
}

#[test]
fn incremental_rebalance_unchanged_on_tree_path() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // The incremental rebalance's subset boundary refresh runs its
    // allgather_u64s through the configured collective path; a mid-run
    // full-swap migration must stay bitwise transparent on tree.
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let run = |coll: &'static str| {
        let deck = deck.clone();
        let results: Arc<Mutex<HashMap<usize, Vec<f32>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let r2 = results.clone();
        World::launch(2, move |rank, world| {
            let mut pin = ParameterInput::from_str(&deck).unwrap();
            pin.apply_override(&format!("parthenon/comm/coll={coll}")).unwrap();
            pin.apply_override("parthenon/exec/space=device").unwrap();
            pin.apply_override("parthenon/exec/strategy=perpack").unwrap();
            pin.apply_override("parthenon/exec/pack_size=2").unwrap();
            let mut sim = HydroSim::new(pin, rank, world).unwrap();
            for s in 0..5 {
                sim.step().unwrap();
                if s == 2 {
                    let new_ranks: Vec<usize> =
                        sim.mesh.ranks.iter().map(|r| 1 - *r).collect();
                    regrid::rebalance_incremental(&mut sim, new_ranks).unwrap();
                }
            }
            sim.sync_device_to_blocks().unwrap();
            let mut res = r2.lock().unwrap();
            for (gid, data) in common::cons_by_gid(&sim) {
                res.insert(gid, data);
            }
        });
        let map = Arc::try_unwrap(results).unwrap().into_inner().unwrap();
        let mut out: Vec<(usize, Vec<f32>)> = map.into_iter().collect();
        out.sort_by_key(|(gid, _)| *gid);
        out
    };
    let flat = run("flat");
    let tree = run("tree");
    assert_eq!(
        common::max_state_diff(&flat, &tree),
        0.0,
        "incremental rebalance must be identical under tree collectives"
    );
}
