//! Simulation-as-a-service (`parthenon::service::Engine`): N concurrent
//! sessions multiplexed onto one shared runtime + worker pool must be
//! bitwise identical to the same N sims run sequentially — final interior
//! state, dt bits, AND checkpoint bytes — across schedulers, worker
//! counts, batching on/off, and mixed uniform/multilevel tenants. A
//! forced-skew run must actually fuse cross-sim launches and steal across
//! the tenant boundary ([`ServiceStats`]), and exactly ONE [`Runtime`] may
//! be constructed per engine, no matter how many sessions attach.
//!
//! [`ServiceStats`]: parthenon::metrics::ServiceStats
//! [`Runtime`]: parthenon::runtime::Runtime

mod common;

use std::sync::Mutex;

use parthenon::config::ParameterInput;
use parthenon::driver::EvolutionDriver;
use parthenon::error::Error;
use parthenon::runtime::Runtime;
use parthenon::service::{Engine, EngineConfig};
use parthenon::util::stealing::StealPolicy;

/// Tests share process-global state (the `PARTHENON_ARTIFACTS` env var,
/// the process-wide Runtime construction counter) — serialize them; a
/// poisoned lock is still a valid gate.
static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// (gid -> interior CONS, dt bits, checkpoint bytes) of one finished sim.
type Fingerprint = (Vec<(usize, Vec<f32>)>, u64, Vec<u8>);

fn fingerprint(sim: &mut parthenon::driver::HydroSim, tag: &str) -> Fingerprint {
    let tmp = std::env::temp_dir().join(format!("parthenon_svc_eq_{tag}.pbin"));
    let tmp_s = tmp.to_str().unwrap().to_string();
    sim.write_restart(&tmp_s).unwrap(); // syncs device staging back first
    let bytes = std::fs::read(&tmp).unwrap();
    let _ = std::fs::remove_file(&tmp);
    (common::cons_by_gid(sim), sim.dt.to_bits(), bytes)
}

fn assert_identical(tag: &str, solo: &Fingerprint, svc: &Fingerprint) {
    assert_eq!(
        common::max_state_diff(&solo.0, &svc.0),
        0.0,
        "{tag}: final state must be bitwise identical"
    );
    assert_eq!(svc.1, solo.1, "{tag}: dt bits must be identical");
    assert_eq!(svc.2, solo.2, "{tag}: checkpoint bytes must be identical");
}

/// Tenant spec: a deck plus its overrides, applied to a fresh pin.
fn pin_for(deck: &str, overrides: &[String]) -> ParameterInput {
    let mut pin = ParameterInput::from_str(deck).unwrap();
    for ov in overrides {
        pin.apply_override(ov).unwrap();
    }
    pin
}

/// The sequential oracle: run each tenant alone for `steps` cycles.
fn run_sequential(tenants: &[(String, Vec<String>)], steps: usize, tag: &str) -> Vec<Fingerprint> {
    tenants
        .iter()
        .enumerate()
        .map(|(i, (deck, ovr))| {
            let ovs: Vec<&str> = ovr.iter().map(|s| s.as_str()).collect();
            let mut sim = common::single_rank_sim(deck, &ovs);
            for _ in 0..steps {
                sim.step().unwrap();
            }
            fingerprint(&mut sim, &format!("{tag}_solo{i}"))
        })
        .collect()
}

/// The service engine: same tenants, one process, `steps` merged cycles.
fn run_engine(
    tenants: &[(String, Vec<String>)],
    cfg: EngineConfig,
    steps: usize,
    tag: &str,
) -> (Vec<Fingerprint>, parthenon::metrics::ServiceStats) {
    let mut engine = Engine::new(cfg).unwrap();
    for (deck, ovr) in tenants {
        engine.add_session(pin_for(deck, ovr)).unwrap();
    }
    for _ in 0..steps {
        assert!(engine.step().unwrap(), "sessions still running");
    }
    let fps = engine
        .sessions_mut()
        .iter_mut()
        .enumerate()
        .map(|(i, s)| fingerprint(&mut s.sim, &format!("{tag}_svc{i}")))
        .collect();
    (fps, engine.stats())
}

fn exec_ovr(space: &str, pack: usize) -> Vec<String> {
    vec![
        format!("parthenon/exec/space={space}"),
        format!("parthenon/exec/strategy=perpack"),
        format!("parthenon/exec/pack_size={pack}"),
    ]
}

#[test]
fn two_sessions_match_sequential_across_sched_workers_batching() {
    let _g = lock();
    // Two device tenants with the SAME block geometry and pack size (so
    // same-key batching can fire) but different mesh sizes (so the merged
    // region is genuinely skewed).
    let tenants = vec![
        (
            common::input_deck("kh", [32, 32, 1], [8, 8, 1], ""),
            exec_ovr("device", 2),
        ),
        (
            common::input_deck("blast", [16, 16, 1], [8, 8, 1], ""),
            exec_ovr("device", 2),
        ),
    ];
    let solo = run_sequential(&tenants, 4, "two");
    for (sname, sched) in [("static", StealPolicy::NoSteal), ("stealing", StealPolicy::Heaviest)] {
        for nw in [1usize, 4] {
            for batching in [false, true] {
                let cfg = EngineConfig {
                    nworkers: nw,
                    sched,
                    multiplex: true,
                    batching,
                    artifact_dir: None,
                };
                let (got, stats) =
                    run_engine(&tenants, cfg, 4, &format!("two_{sname}_{nw}_{batching}"));
                for (i, (s, g)) in solo.iter().zip(got.iter()).enumerate() {
                    assert_identical(
                        &format!("tenant {i} sched={sname} nw={nw} batching={batching}"),
                        s,
                        g,
                    );
                }
                assert_eq!(stats.sessions_live, 2);
                if !batching {
                    assert_eq!(
                        stats.batched_launches, 0,
                        "batching off must never fuse launches"
                    );
                }
            }
        }
    }
}

#[test]
fn multiplex_off_is_the_sequential_oracle() {
    let _g = lock();
    let tenants = vec![
        (
            common::input_deck("kh", [32, 32, 1], [8, 8, 1], ""),
            exec_ovr("host", 2),
        ),
        (
            common::input_deck("blast", [16, 16, 1], [8, 8, 1], ""),
            exec_ovr("device", 2),
        ),
    ];
    let solo = run_sequential(&tenants, 4, "mux_off");
    let cfg = EngineConfig {
        multiplex: false,
        batching: false,
        ..EngineConfig::default()
    };
    let (got, stats) = run_engine(&tenants, cfg, 4, "mux_off");
    for (i, (s, g)) in solo.iter().zip(got.iter()).enumerate() {
        assert_identical(&format!("multiplex-off tenant {i}"), s, g);
    }
    assert_eq!(stats.batched_launches, 0);
    assert_eq!(stats.cross_sim_steals, 0);
}

#[test]
fn eight_mixed_sessions_match_sequential() {
    let _g = lock();
    if !common::multi_rank_enabled() {
        return; // heavyweight lane runs in the multi-rank CI step
    }
    // 8 tenants mixing execution spaces, problems, and mesh hierarchies:
    // six uniform (host and device alternating) plus one multilevel device
    // tenant (general mode — excluded from batching by construction) and
    // one multilevel host tenant.
    let ml_extra = "\n<parthenon/mesh>\nrefinement = static\nnumlevel = 2\n\n\
                    <parthenon/static_refinement0>\nlevel = 1\n\
                    x1min = 0.3\nx1max = 0.7\nx2min = 0.3\nx2max = 0.7\n";
    let mut tenants = Vec::new();
    for i in 0..6 {
        let space = if i % 2 == 0 { "device" } else { "host" };
        let problem = if i % 3 == 0 { "kh" } else { "blast" };
        tenants.push((
            common::input_deck(problem, [16, 16, 1], [8, 8, 1], ""),
            exec_ovr(space, 2),
        ));
    }
    tenants.push((
        common::input_deck("blast", [16, 16, 1], [4, 4, 1], ml_extra),
        exec_ovr("device", 2),
    ));
    tenants.push((
        common::input_deck("blast", [16, 16, 1], [4, 4, 1], ml_extra),
        exec_ovr("host", 2),
    ));
    let solo = run_sequential(&tenants, 3, "eight");
    let cfg = EngineConfig {
        nworkers: 4,
        sched: StealPolicy::Heaviest,
        multiplex: true,
        batching: true,
        artifact_dir: None,
    };
    let (got, stats) = run_engine(&tenants, cfg, 3, "eight");
    for (i, (s, g)) in solo.iter().zip(got.iter()).enumerate() {
        assert_identical(&format!("8-tenant mix, tenant {i}"), s, g);
    }
    assert_eq!(stats.sessions_live, 8);
    // the six same-shape uniform device tenants guarantee fused launches
    assert!(stats.batched_launches >= 1, "{stats:?}");
}

#[test]
fn forced_skew_batches_cross_sim_and_steals_cross_tenant() {
    let _g = lock();
    // One big and one small device tenant with identical block geometry:
    // every stage, their same-key packs rendezvous into ONE fused launch
    // (4x pack-count skew), and with stealing workers the tenant boundary
    // must be crossed. Exactly ONE Runtime may be constructed for the
    // whole engine, sessions included.
    let tenants = vec![
        (
            common::input_deck("kh", [64, 64, 1], [8, 8, 1], ""),
            exec_ovr("device", 2),
        ),
        (
            common::input_deck("blast", [32, 32, 1], [8, 8, 1], ""),
            exec_ovr("device", 2),
        ),
    ];
    let cfg = EngineConfig {
        nworkers: 2,
        sched: StealPolicy::Heaviest,
        multiplex: true,
        batching: true,
        artifact_dir: None,
    };
    let rt0 = Runtime::constructed_count();
    let mut engine = Engine::new(cfg).unwrap();
    for (deck, ovr) in &tenants {
        engine.add_session(pin_for(deck, ovr)).unwrap();
    }
    assert_eq!(
        Runtime::constructed_count() - rt0,
        1,
        "one engine, N sessions: exactly one Runtime"
    );
    for _ in 0..12 {
        assert!(engine.step().unwrap());
    }
    let stats = engine.stats();
    assert_eq!(stats.sessions_live, 2);
    assert!(
        stats.batched_launches >= 1,
        "same-key cross-sim packs must fuse: {stats:?}"
    );
    assert!(
        stats.launches_saved >= 1,
        "every fused batch of n packs saves n-1 launches: {stats:?}"
    );
    assert!(
        stats.cross_sim_steals >= 1,
        "idle workers must steal across the tenant boundary: {stats:?}"
    );
    // A single-session engine must never fuse: its same-key packs form a
    // single-sim group, which dissolves at seal (solo launches only).
    let cfg1 = EngineConfig {
        nworkers: 2,
        sched: StealPolicy::Heaviest,
        multiplex: true,
        batching: true,
        artifact_dir: None,
    };
    let mut one = Engine::new(cfg1).unwrap();
    one.add_session(pin_for(&tenants[1].0, &tenants[1].1)).unwrap();
    for _ in 0..3 {
        assert!(one.step().unwrap());
    }
    let s1 = one.stats();
    assert_eq!(s1.batched_launches, 0, "single-sim groups must dissolve: {s1:?}");
    assert_eq!(s1.cross_sim_steals, 0, "one tenant: nothing to steal across");
}

#[test]
fn corrupt_artifact_dir_fails_once_at_engine_build() {
    let _g = lock();
    // The bugfix satellite: the shared Runtime is constructed ONCE by the
    // engine, so a corrupt artifact dir surfaces there — a structured
    // error before any session exists, not N panics inside rank threads.
    let dir = std::env::temp_dir().join("parthenon_svc_eq_badmanifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), b"{ this is not json").unwrap();
    let cfg = EngineConfig {
        artifact_dir: Some(dir.clone()),
        ..EngineConfig::default()
    };
    let err = Engine::new(cfg).err().expect("corrupt manifest must fail the build");
    assert!(
        matches!(err, Error::Runtime(_) | Error::Artifact(_) | Error::Json(_)),
        "corrupt manifest must surface as a structured error, got {err:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
