//! Fused stage pipeline (`parthenon/exec overlap = fused`) vs the
//! barrier-phased oracle: the fused per-pack task lists overlap boundary
//! exchange with compute, but must be BITWISE identical to the phased
//! schedule on every worker count, every steal order, both execution
//! spaces, and on multilevel meshes with flux correction — plus the
//! overlap contract itself (sends posted before a pack's first
//! `Incomplete` poll) and the load-balance cost fixes that ride along.

mod common;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use parthenon::comm::World;
use parthenon::config::ParameterInput;
use parthenon::driver::{regrid, EvolutionDriver, HydroSim};

/// Run `deck` single-rank for `steps` with the given overrides; return
/// gid -> interior CONS (device staging scattered back first).
fn run_sim(deck: &str, overrides: &[&str], steps: usize) -> Vec<(usize, Vec<f32>)> {
    let mut sim = common::single_rank_sim(deck, overrides);
    for _ in 0..steps {
        sim.step().unwrap();
    }
    sim.sync_device_to_blocks().unwrap();
    common::cons_by_gid(&sim)
}

#[test]
fn fused_matches_phased_host_across_workers_and_scheds() {
    // 64 blocks, pack_size 4 -> 16 packs: enough lists to interleave.
    let deck = common::input_deck("kh", [32, 32, 1], [4, 4, 1], "");
    let base = run_sim(
        &deck,
        &[
            "parthenon/exec/overlap=phased",
            "parthenon/exec/sched=static",
            "parthenon/exec/nworkers=1",
            "parthenon/exec/pack_size=4",
        ],
        4,
    );
    for sched in ["static", "stealing", "roundrobin", "reverse"] {
        for nw in [1usize, 2, 4, 8] {
            let ov_sched = format!("parthenon/exec/sched={sched}");
            let ov_nw = format!("parthenon/exec/nworkers={nw}");
            let got = run_sim(
                &deck,
                &[
                    "parthenon/exec/overlap=fused",
                    &ov_sched,
                    &ov_nw,
                    "parthenon/exec/pack_size=4",
                ],
                4,
            );
            assert_eq!(
                common::max_state_diff(&base, &got),
                0.0,
                "fused sched={sched} nworkers={nw} must be bitwise identical \
                 to the phased oracle"
            );
        }
    }
}

#[test]
fn fused_matches_phased_multilevel_with_flux_correction() {
    // Static refinement -> multilevel: the fused lists carry the
    // flux-correction send/poll tasks too.
    let deck = common::input_deck("blast", [32, 32, 1], [8, 8, 1], "");
    let ml = [
        "parthenon/mesh/refinement=static",
        "parthenon/mesh/numlevel=2",
        "parthenon/static_refinement0/level=1",
        "parthenon/static_refinement0/x1min=0.3",
        "parthenon/static_refinement0/x1max=0.7",
        "parthenon/static_refinement0/x2min=0.3",
        "parthenon/static_refinement0/x2max=0.7",
        "parthenon/exec/pack_size=2",
    ];
    let mut base_ov: Vec<&str> = ml.to_vec();
    base_ov.push("parthenon/exec/overlap=phased");
    base_ov.push("parthenon/exec/sched=static");
    base_ov.push("parthenon/exec/nworkers=1");
    let base = run_sim(&deck, &base_ov, 4);
    assert!(base.len() > 16, "refinement must have produced extra blocks");
    for (sched, nw) in [
        ("static", 1usize),
        ("stealing", 2),
        ("stealing", 4),
        ("roundrobin", 4),
        ("reverse", 4),
    ] {
        let ov_sched = format!("parthenon/exec/sched={sched}");
        let ov_nw = format!("parthenon/exec/nworkers={nw}");
        let mut got_ov: Vec<&str> = ml.to_vec();
        got_ov.push("parthenon/exec/overlap=fused");
        got_ov.push(&ov_sched);
        got_ov.push(&ov_nw);
        let got = run_sim(&deck, &got_ov, 4);
        assert_eq!(
            common::max_state_diff(&base, &got),
            0.0,
            "multilevel fused sched={sched} nworkers={nw}"
        );
    }
}

#[test]
fn fused_matches_phased_device_all_strategies() {
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // 16 blocks, pack_size 4: per-pack launch/send/poll lists interleave.
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    for strategy in ["perpack", "perblock", "perbuffer"] {
        let ov_strat = format!("parthenon/exec/strategy={strategy}");
        let phased = run_sim(
            &deck,
            &[
                "parthenon/exec/space=device",
                &ov_strat,
                "parthenon/exec/pack_size=4",
                "parthenon/exec/overlap=phased",
            ],
            4,
        );
        let fused = run_sim(
            &deck,
            &[
                "parthenon/exec/space=device",
                &ov_strat,
                "parthenon/exec/pack_size=4",
                "parthenon/exec/overlap=fused",
            ],
            4,
        );
        assert_eq!(
            common::max_state_diff(&phased, &fused),
            0.0,
            "device fused strategy={strategy} must be bitwise identical"
        );
    }
}

#[test]
fn fused_posts_all_sends_before_first_incomplete_poll() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    // 2 ranks so receives genuinely wait on a peer: the poll tasks DO
    // return Incomplete, and the instrumentation proves every pack's
    // sends were already posted when they did.
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let stats: Arc<Mutex<Vec<(u64, u64, u64, u64)>>> = Arc::new(Mutex::new(Vec::new()));
    let s2 = stats.clone();
    World::launch(2, move |rank, world| {
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        pin.apply_override("parthenon/exec/overlap=fused").unwrap();
        pin.apply_override("parthenon/exec/pack_size=2").unwrap();
        pin.apply_override("parthenon/exec/nworkers=2").unwrap();
        let mut sim = HydroSim::new(pin, rank, world).unwrap();
        for _ in 0..4 {
            sim.step().unwrap();
        }
        let os = sim.host.as_ref().expect("host exec").overlap_stats();
        s2.lock().unwrap().push((
            os.packs_posted.load(std::sync::atomic::Ordering::SeqCst),
            os.segments_sent.load(std::sync::atomic::Ordering::SeqCst),
            os.incomplete_polls.load(std::sync::atomic::Ordering::SeqCst),
            os.early_poll_violations.load(std::sync::atomic::Ordering::SeqCst),
        ));
    });
    let stats = stats.lock().unwrap();
    assert_eq!(stats.len(), 2);
    for (rank, (posted, segs, _incomplete, violations)) in stats.iter().enumerate() {
        // 8 blocks / pack_size 2 = 4 packs, 2 stages x 4 cycles = 8 stage
        // sweeps -> 32 send tasks per rank.
        assert_eq!(*posted, 32, "rank {rank}: every pack posts every stage");
        assert!(*segs > 0, "rank {rank}: sends must carry segments");
        assert_eq!(
            *violations, 0,
            "rank {rank}: a pack's sends must be posted before its poll \
             first returns Incomplete"
        );
    }
}

/// The cost EWMA must ride the migration payload: after a full-swap
/// rebalance every block's measured cost (including an artificial
/// sentinel) must be bit-identical on its new rank.
#[test]
fn migrated_blocks_keep_measured_cost_ewma() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let recorded: Arc<Mutex<HashMap<usize, u64>>> = Arc::new(Mutex::new(HashMap::new()));
    let r2 = recorded.clone();
    World::launch(2, move |rank, world| {
        let pin = ParameterInput::from_str(&deck).unwrap();
        let mut sim = HydroSim::new(pin, rank, world).unwrap();
        for _ in 0..3 {
            sim.step().unwrap(); // EWMA warms up from measured timings
        }
        if rank == 0 {
            // sentinel no measurement could produce by coincidence
            sim.mesh.blocks[0].cost = 7.25;
        }
        {
            let mut rec = r2.lock().unwrap();
            for b in &sim.mesh.blocks {
                rec.insert(b.gid, b.cost.to_bits());
            }
        }
        // Recording happens before rebalance posts any sends, so by the
        // time a rank's rebalance returns (it received the peer's blocks)
        // the peer's entries are in the map.
        let new_ranks: Vec<usize> = sim.mesh.ranks.iter().map(|r| 1 - *r).collect();
        regrid::rebalance(&mut sim, new_ranks).unwrap();
        let rec = r2.lock().unwrap();
        for b in &sim.mesh.blocks {
            assert_eq!(
                b.cost.to_bits(),
                rec[&b.gid],
                "rank {rank}: block {} lost its measured cost EWMA across \
                 migration",
                b.gid
            );
        }
    });
    assert_eq!(recorded.lock().unwrap().len(), 16);
}

#[test]
fn device_costs_are_measured_not_nominal() {
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let mut sim = common::single_rank_sim(
        &deck,
        &[
            "parthenon/exec/space=device",
            "parthenon/exec/strategy=perpack",
            "parthenon/exec/pack_size=4",
        ],
    );
    for _ in 0..3 {
        sim.step().unwrap();
    }
    let costs: Vec<f64> = sim.mesh.blocks.iter().map(|b| b.cost).collect();
    assert!(
        costs.iter().any(|c| (c - 1.0).abs() > 1e-9),
        "Device launch timings must move MeshBlock::cost off nominal"
    );
    let mean = costs.iter().sum::<f64>() / costs.len() as f64;
    assert!(
        (mean - 1.0).abs() < 0.5,
        "normalized cost mean should stay near 1, got {mean}"
    );
    assert!(costs.iter().all(|c| *c > 0.0));
}
