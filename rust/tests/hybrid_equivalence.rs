//! Heterogeneous co-execution (`parthenon/exec space=hybrid`): the merged
//! one-region scheduler must be bitwise identical to the single-space
//! paths at the forced-split endpoints — `hybrid_split=0.0` against
//! `space=host` and `hybrid_split=1.0` against `space=device` — across
//! schedulers, worker counts, mesh levels, and rank counts, measured on
//! the final interior state, the dt bits, AND the checkpoint bytes. A
//! forced-skew run must actually exercise both spaces in one TaskRegion
//! and steal across the space boundary; misconfigurations must surface as
//! structured errors, never panics.

mod common;

use std::sync::{Arc, Mutex};

use parthenon::comm::World;
use parthenon::config::ParameterInput;
use parthenon::driver::{EvolutionDriver, HydroSim};
use parthenon::error::Error;

/// Tests share process-global state (the `PARTHENON_ARTIFACTS` env var,
/// worker pools) — serialize them; a poisoned lock is still a valid gate.
static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `deck` single-rank for `steps`; return (gid -> interior CONS,
/// dt bits, restart-file bytes).
fn run(
    deck: &str,
    overrides: &[String],
    steps: usize,
    tag: &str,
) -> (Vec<(usize, Vec<f32>)>, u64, Vec<u8>) {
    let ovs: Vec<&str> = overrides.iter().map(|s| s.as_str()).collect();
    let mut sim = common::single_rank_sim(deck, &ovs);
    for _ in 0..steps {
        sim.step().unwrap();
    }
    let tmp = std::env::temp_dir().join(format!("parthenon_hybrid_eq_{tag}.pbin"));
    let tmp_s = tmp.to_str().unwrap().to_string();
    sim.write_restart(&tmp_s).unwrap(); // syncs device staging back first
    let bytes = std::fs::read(&tmp).unwrap();
    let _ = std::fs::remove_file(&tmp);
    (common::cons_by_gid(&sim), sim.dt.to_bits(), bytes)
}

fn base_ovr(space: &str, sched: &str, nw: usize, pack: usize) -> Vec<String> {
    vec![
        format!("parthenon/exec/space={space}"),
        format!("parthenon/exec/sched={sched}"),
        format!("parthenon/exec/nworkers={nw}"),
        format!("parthenon/exec/pack_size={pack}"),
    ]
}

fn assert_identical(
    tag: &str,
    base: &(Vec<(usize, Vec<f32>)>, u64, Vec<u8>),
    got: &(Vec<(usize, Vec<f32>)>, u64, Vec<u8>),
) {
    assert_eq!(
        common::max_state_diff(&base.0, &got.0),
        0.0,
        "{tag}: final state must be bitwise identical"
    );
    assert_eq!(got.1, base.1, "{tag}: dt bits must be identical");
    assert_eq!(got.2, base.2, "{tag}: checkpoint bytes must be identical");
}

#[test]
fn hybrid_split_zero_matches_host_uniform() {
    let _g = lock();
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    for sched in ["static", "stealing"] {
        for nw in [1usize, 4, 8] {
            let base = run(&deck, &base_ovr("host", sched, nw, 4), 4, "h0_base");
            let mut ov = base_ovr("hybrid", sched, nw, 4);
            ov.push("parthenon/exec/hybrid_split=0.0".into());
            let got = run(&deck, &ov, 4, "h0_hyb");
            assert_identical(
                &format!("uniform split=0.0 vs host sched={sched} nw={nw}"),
                &base,
                &got,
            );
        }
    }
}

#[test]
fn hybrid_split_zero_matches_host_multilevel() {
    let _g = lock();
    // Multilevel: a general-mode DeviceState exists now, but split=0.0
    // pins every pack to the Host space — the run must still be bitwise
    // the host path, with flux correction live.
    let deck = common::input_deck("blast", [16, 16, 1], [4, 4, 1], "");
    let ml = [
        "parthenon/mesh/refinement=static",
        "parthenon/mesh/numlevel=2",
        "parthenon/static_refinement0/level=1",
        "parthenon/static_refinement0/x1min=0.3",
        "parthenon/static_refinement0/x1max=0.7",
        "parthenon/static_refinement0/x2min=0.3",
        "parthenon/static_refinement0/x2max=0.7",
    ];
    for sched in ["static", "stealing"] {
        for nw in [1usize, 4] {
            let mut bo = base_ovr("host", sched, nw, 2);
            bo.extend(ml.iter().map(|s| s.to_string()));
            let base = run(&deck, &bo, 3, "ml_base");
            let mut ho = base_ovr("hybrid", sched, nw, 2);
            ho.extend(ml.iter().map(|s| s.to_string()));
            ho.push("parthenon/exec/hybrid_split=0.0".into());
            let got = run(&deck, &ho, 3, "ml_hyb");
            assert_identical(
                &format!("multilevel split=0.0 vs host sched={sched} nw={nw}"),
                &base,
                &got,
            );
        }
    }
}

#[test]
fn hybrid_split_one_matches_device_uniform() {
    let _g = lock();
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    for sched in ["static", "stealing"] {
        for nw in [1usize, 4, 8] {
            let base = run(&deck, &base_ovr("device", sched, nw, 4), 4, "d1_base");
            let mut ov = base_ovr("hybrid", sched, nw, 4);
            ov.push("parthenon/exec/hybrid_split=1.0".into());
            let got = run(&deck, &ov, 4, "d1_hyb");
            assert_identical(
                &format!("uniform split=1.0 vs device sched={sched} nw={nw}"),
                &base,
                &got,
            );
        }
    }
}

/// Two-rank run; returns (sorted gid -> interior CONS, rank-0 dt bits,
/// restart-file bytes).
fn run_tworank(
    deck: String,
    overrides: Vec<String>,
    steps: usize,
    tag: &str,
) -> (Vec<(usize, Vec<f32>)>, u64, Vec<u8>) {
    let state: Arc<Mutex<Vec<(usize, Vec<f32>)>>> = Arc::new(Mutex::new(Vec::new()));
    let dt_bits = Arc::new(Mutex::new(0u64));
    let tmp = std::env::temp_dir().join(format!("parthenon_hybrid_eq_{tag}.pbin"));
    let tmp_s = tmp.to_str().unwrap().to_string();
    {
        let (st, db) = (state.clone(), dt_bits.clone());
        World::launch(2, move |rank, world| {
            let mut pin = ParameterInput::from_str(&deck).unwrap();
            for ov in &overrides {
                pin.apply_override(ov).unwrap();
            }
            let mut sim = HydroSim::new(pin, rank, world).unwrap();
            for _ in 0..steps {
                sim.step().unwrap();
            }
            sim.write_restart(&tmp_s).unwrap(); // collective; rank 0 writes
            let mut blocks = common::cons_by_gid(&sim);
            st.lock().unwrap().append(&mut blocks);
            if rank == 0 {
                *db.lock().unwrap() = sim.dt.to_bits();
            }
        });
    }
    let mut s = Arc::try_unwrap(state).unwrap().into_inner().unwrap();
    s.sort_by_key(|(g, _)| *g);
    let dt = *dt_bits.lock().unwrap();
    let bytes = std::fs::read(&tmp).unwrap();
    let _ = std::fs::remove_file(&tmp);
    (s, dt, bytes)
}

#[test]
fn hybrid_endpoints_match_single_space_on_two_ranks() {
    let _g = lock();
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let base = run_tworank(deck.clone(), base_ovr("host", "stealing", 4, 4), 3, "r2_host");
    let mut ov = base_ovr("hybrid", "stealing", 4, 4);
    ov.push("parthenon/exec/hybrid_split=0.0".into());
    let got = run_tworank(deck.clone(), ov, 3, "r2_hyb0");
    assert_identical("2-rank split=0.0 vs host", &base, &got);

    if common::artifacts_available() {
        let base = run_tworank(deck.clone(), base_ovr("device", "stealing", 4, 4), 3, "r2_dev");
        let mut ov = base_ovr("hybrid", "stealing", 4, 4);
        ov.push("parthenon/exec/hybrid_split=1.0".into());
        let got = run_tworank(deck, ov, 3, "r2_hyb1");
        assert_identical("2-rank split=1.0 vs device", &base, &got);
    }
}

#[test]
fn exec_space_misconfiguration_is_a_structured_error() {
    let _g = lock();
    let deck = common::input_deck("kh", [16, 16, 1], [8, 8, 1], "");

    // unknown space value -> Config error from parameter parsing
    let mut pin = ParameterInput::from_str(&deck).unwrap();
    pin.apply_override("parthenon/exec/space=warp").unwrap();
    let err = HydroSim::new(pin, 0, World::new(1))
        .err()
        .expect("unknown exec space must be rejected");
    assert!(
        matches!(err, Error::Config(_)),
        "unknown space must be a Config error, got {err:?}"
    );

    // device|hybrid with a corrupt runtime manifest -> structured error,
    // not a panic (a MISSING manifest falls back to the native
    // interpreter, so corruption is the reachable failure here)
    let dir = std::env::temp_dir().join("parthenon_hybrid_eq_badmanifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), b"{ this is not json").unwrap();
    std::env::set_var("PARTHENON_ARTIFACTS", &dir);
    for space in ["device", "hybrid"] {
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        pin.apply_override(&format!("parthenon/exec/space={space}"))
            .unwrap();
        let err = HydroSim::new(pin, 0, World::new(1))
            .err()
            .unwrap_or_else(|| panic!("space={space} with a corrupt manifest must error"));
        assert!(
            matches!(err, Error::Runtime(_) | Error::Artifact(_) | Error::Json(_)),
            "space={space}: corrupt manifest must surface as a structured \
             runtime/artifact error, got {err:?}"
        );
    }
    std::env::remove_var("PARTHENON_ARTIFACTS");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn auto_hybrid_on_one_worker_degenerates_to_pure_host() {
    let _g = lock();
    // Automatic split with nobody to overlap with: every pack must land on
    // the host, and the run must still be a valid hybrid-space run.
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let mut sim = common::single_rank_sim(
        &deck,
        &[
            "parthenon/exec/space=hybrid",
            "parthenon/exec/nworkers=1",
            "parthenon/exec/pack_size=4",
        ],
    );
    for _ in 0..3 {
        sim.step().unwrap();
    }
    assert_eq!(
        sim.hybrid_stats.packs_device, 0,
        "auto split on one worker must not schedule device packs"
    );
    assert!(sim.hybrid_stats.packs_host > 0);
    assert_eq!(sim.hybrid_stats.cross_space_steals, 0);
}

#[test]
fn forced_skew_runs_both_spaces_and_steals_across_the_boundary() {
    let _g = lock();
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // 8 packs forcibly split 4/4 over 2 stealing workers: both spaces
    // execute in the SAME TaskRegion every stage, and whichever worker
    // drains its own space's lists first must steal across the boundary.
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let mut sim = common::single_rank_sim(
        &deck,
        &[
            "parthenon/exec/space=hybrid",
            "parthenon/exec/hybrid_split=0.5",
            "parthenon/exec/sched=stealing",
            "parthenon/exec/nworkers=2",
            "parthenon/exec/pack_size=2",
        ],
    );
    for _ in 0..12 {
        sim.step().unwrap();
    }
    let hs = &sim.hybrid_stats;
    assert!(
        hs.packs_host > 0 && hs.packs_device > 0,
        "both spaces must execute packs, got {hs:?}"
    );
    assert!(
        hs.cross_space_steals >= 1,
        "idle workers must steal across the space boundary, got {hs:?}"
    );
    // the single-space paths must leave these counters untouched
    let mut host_sim = common::single_rank_sim(&deck, &["parthenon/exec/space=host"]);
    host_sim.step().unwrap();
    assert!(host_sim.hybrid_stats.is_untouched());
}
