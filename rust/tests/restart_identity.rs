//! Restart correctness (paper Sec. 3.9): a run interrupted by a restart
//! file must continue bitwise identically, including when resumed on a
//! different number of ranks.

mod common;

use parthenon::comm::World;
use parthenon::config::ParameterInput;
use parthenon::driver::{EvolutionDriver, HydroSim};
use parthenon::io::Snapshot;
use std::sync::{Arc, Mutex};

fn deck() -> String {
    common::input_deck("blast", [32, 32, 1], [16, 16, 1], "")
}

#[test]
fn restart_is_bitwise_identical() {
    let tmp = std::env::temp_dir().join("parthenon_restart_test.pbin");
    let tmp_s = tmp.to_str().unwrap().to_string();

    // straight run: 10 cycles
    let mut straight = common::single_rank_sim(&deck(), &[]);
    for _ in 0..10 {
        straight.step().unwrap();
    }
    let expect = common::cons_by_gid(&straight);

    // interrupted run: 6 cycles, restart, 4 more
    let mut first = common::single_rank_sim(&deck(), &[]);
    for _ in 0..6 {
        first.step().unwrap();
    }
    first.write_restart(&tmp_s).unwrap();

    let mut resumed = common::single_rank_sim(&deck(), &[]);
    let snap = Snapshot::read(&tmp_s).unwrap();
    resumed.restore_snapshot(&snap).unwrap();
    assert_eq!(resumed.cycle, 6);
    for _ in 0..4 {
        resumed.step().unwrap();
    }
    let got = common::cons_by_gid(&resumed);

    let diff = common::max_state_diff(&expect, &got);
    assert_eq!(diff, 0.0, "restart must be bitwise identical");
    assert_eq!(straight.time.to_bits(), resumed.time.to_bits());
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn restart_across_rank_counts() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    let tmp = std::env::temp_dir().join("parthenon_restart_ranks.pbin");
    let tmp_s = tmp.to_str().unwrap().to_string();

    // write from a 2-rank run after 5 cycles
    {
        let deck = deck();
        let tmp_s = tmp_s.clone();
        World::launch(2, move |rank, world| {
            let pin = ParameterInput::from_str(&deck).unwrap();
            let mut sim = HydroSim::new(pin, rank, world).unwrap();
            for _ in 0..5 {
                sim.step().unwrap();
            }
            sim.write_restart(&tmp_s).unwrap();
        });
    }

    // resume on 1 rank for 5 more cycles
    let mut resumed = common::single_rank_sim(&deck(), &[]);
    let snap = Snapshot::read(&tmp_s).unwrap();
    resumed.restore_snapshot(&snap).unwrap();
    for _ in 0..5 {
        resumed.step().unwrap();
    }
    let got = common::cons_by_gid(&resumed);

    // compare against a straight 10-cycle run gathered from 3 ranks (any
    // rank count must give the same physics)
    let expect: Arc<Mutex<Vec<(usize, Vec<f32>)>>> = Arc::new(Mutex::new(Vec::new()));
    {
        let deck = deck();
        let e2 = expect.clone();
        World::launch(3, move |rank, world| {
            let pin = ParameterInput::from_str(&deck).unwrap();
            let mut sim = HydroSim::new(pin, rank, world).unwrap();
            for _ in 0..10 {
                sim.step().unwrap();
            }
            let mut blocks = common::cons_by_gid(&sim);
            e2.lock().unwrap().append(&mut blocks);
        });
    }
    let mut expect = Arc::try_unwrap(expect).unwrap().into_inner().unwrap();
    expect.sort_by_key(|(g, _)| *g);

    let diff = common::max_state_diff(&expect, &got);
    assert_eq!(
        diff, 0.0,
        "physics must be independent of rank layout and restart"
    );
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn restart_matrix_exec_and_sched_bitwise() {
    // Snapshot at cycle 4, restore into a fresh sim, run to 8: bitwise
    // identical state AND dt bits versus an uninterrupted run of the same
    // configuration, for every exec-space x scheduler combination. This is
    // the determinism contract the crash-recovery loop leans on: a
    // recovered run must be indistinguishable from one that never died.
    let configs: &[&[&str]] = &[
        &["parthenon/exec/space=host", "parthenon/exec/sched=static"],
        &["parthenon/exec/space=host", "parthenon/exec/sched=stealing"],
        &["parthenon/exec/space=device", "parthenon/exec/sched=static"],
        &["parthenon/exec/space=device", "parthenon/exec/sched=stealing"],
    ];
    for ovr in configs {
        let is_device = ovr.iter().any(|o| o.ends_with("=device"));
        if is_device && !common::artifacts_available() {
            eprintln!("skipping {ovr:?}: artifacts not built");
            continue;
        }
        let tag = ovr.join("+");
        let tmp = std::env::temp_dir().join(format!(
            "parthenon_restart_matrix_{}_{}.pbin",
            if is_device { "dev" } else { "host" },
            ovr[1].rsplit('=').next().unwrap()
        ));
        let tmp_s = tmp.to_str().unwrap().to_string();

        // uninterrupted 8 cycles
        let mut straight = common::single_rank_sim(&deck(), ovr);
        for _ in 0..8 {
            straight.step().unwrap();
        }
        straight.sync_device_to_blocks().unwrap();
        let expect = common::cons_by_gid(&straight);

        // interrupted at cycle 4
        let mut first = common::single_rank_sim(&deck(), ovr);
        for _ in 0..4 {
            first.step().unwrap();
        }
        first.write_restart(&tmp_s).unwrap();

        let mut resumed = common::single_rank_sim(&deck(), ovr);
        let snap = Snapshot::read(&tmp_s).unwrap();
        resumed.restore_snapshot(&snap).unwrap();
        assert_eq!(resumed.cycle, 4, "{tag}");
        for _ in 0..4 {
            resumed.step().unwrap();
        }
        resumed.sync_device_to_blocks().unwrap();
        let got = common::cons_by_gid(&resumed);

        let diff = common::max_state_diff(&expect, &got);
        assert_eq!(diff, 0.0, "{tag}: restart must be bitwise identical");
        assert_eq!(
            straight.dt.to_bits(),
            resumed.dt.to_bits(),
            "{tag}: dt bits must match"
        );
        assert_eq!(
            straight.time.to_bits(),
            resumed.time.to_bits(),
            "{tag}: time bits must match"
        );
        let _ = std::fs::remove_file(&tmp);
    }
}

#[test]
fn snapshot_roundtrip_preserves_header() {
    let tmp = std::env::temp_dir().join("parthenon_snap_header.pbin");
    let tmp_s = tmp.to_str().unwrap().to_string();
    let mut sim = common::single_rank_sim(&deck(), &[]);
    for _ in 0..3 {
        sim.step().unwrap();
    }
    sim.write_restart(&tmp_s).unwrap();
    let snap = Snapshot::read(&tmp_s).unwrap();
    assert_eq!(snap.cycle, 3);
    assert_eq!(snap.dim, 2);
    assert_eq!(snap.block_nx, [16, 16, 1]);
    assert_eq!(snap.leaves.len(), 4);
    assert_eq!(snap.time.to_bits(), sim.time.to_bits());
    assert_eq!(snap.dt.to_bits(), sim.dt.to_bits());
    // block data accessible per gid
    for gid in 0..4 {
        let data = snap.block_var(gid, "cons").unwrap();
        assert_eq!(data.len(), 5 * 16 * 16);
        assert!(data.iter().all(|x| x.is_finite()));
    }
    let _ = std::fs::remove_file(&tmp);
}
