//! Pack-cache lifecycle: the MeshData pack partition must be rebuilt after
//! every mesh change (AMR regrid, load-balance shuffle, restart), running a
//! stage on stale packs must be impossible, and the pack partition must not
//! change results on the Host path (pack-parallel == sequential numerics).

mod common;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use parthenon::comm::World;
use parthenon::config::ParameterInput;
use parthenon::driver::{regrid, EvolutionDriver, HydroSim};
use parthenon::hydro::CONS;
use parthenon::mesh_data::MeshData;

fn amr_overrides() -> Vec<&'static str> {
    vec![
        "parthenon/mesh/refinement=adaptive",
        "parthenon/mesh/numlevel=2",
        "parthenon/mesh/check_refine_interval=3",
        "hydro/refine_criterion=pressure_gradient",
        "hydro/refine_tol=0.25",
        "hydro/derefine_tol=0.03",
    ]
}

#[test]
fn pack_plan_honors_pack_size() {
    // 64-block mesh
    let deck = common::input_deck("uniform", [64, 64, 1], [8, 8, 1], "");
    for (ps, expect_packs) in [(1usize, 64usize), (4, 16), (16, 4), (64, 1)] {
        let ov = format!("parthenon/exec/pack_size={ps}");
        let sim = common::single_rank_sim(&deck, &[&ov]);
        assert_eq!(sim.mesh_data.nblocks(), 64);
        assert_eq!(sim.mesh_data.npacks(), expect_packs, "pack_size {ps}");
        assert_eq!(sim.mesh_data.pack_size(), ps);
        let total: usize = sim.mesh_data.packs().iter().map(|d| d.nb).sum();
        assert_eq!(total, 64);
    }
}

#[test]
fn stage_on_stale_packs_is_impossible() {
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let mut sim = common::single_rank_sim(&deck, &[]);
    sim.step().unwrap();

    // Simulate a mesh change that bypasses the driver's rebuild hook (the
    // failure mode the version pin exists to catch).
    sim.mesh.rebuild_local_blocks();
    assert!(sim.mesh_data.validate(&sim.mesh).is_err());
    let err = sim.step().unwrap_err();
    let msg = format!("{err}");
    assert!(
        msg.contains("stale MeshData"),
        "expected stale-pack error, got: {msg}"
    );
}

#[test]
fn standalone_meshdata_tracks_mesh_version() {
    let deck = common::input_deck("uniform", [32, 32, 1], [8, 8, 1], "");
    let sim = common::single_rank_sim(&deck, &[]);
    let mut md = MeshData::build(&sim.mesh, 4, None);
    assert!(md.validate(&sim.mesh).is_ok());
    assert_eq!(md.built_version(), sim.mesh.version);
    md.invalidate();
    assert!(md.validate(&sim.mesh).is_err());
    assert!(md.ensure_current(&sim.mesh, None));
    assert!(md.validate(&sim.mesh).is_ok());
}

#[test]
fn amr_regrid_rebuilds_packs() {
    let deck = common::input_deck("blast", [32, 32, 1], [8, 8, 1], "");
    let mut sim = common::single_rank_sim(&deck, &amr_overrides());
    let blocks0 = sim.mesh.blocks.len();
    let v0 = sim.mesh.version;
    let mut regridded = false;
    for _ in 0..18 {
        sim.step().unwrap();
        // invariant at every cycle: the pack plan matches the live mesh
        assert!(sim.mesh_data.validate(&sim.mesh).is_ok());
        assert_eq!(sim.mesh_data.nblocks(), sim.mesh.blocks.len());
        let total: usize = sim.mesh_data.packs().iter().map(|d| d.nb).sum();
        assert_eq!(total, sim.mesh.blocks.len());
        if sim.mesh.version != v0 {
            regridded = true;
        }
    }
    assert!(
        regridded && sim.mesh.blocks.len() != blocks0,
        "blast must trigger an AMR regrid for this test to bite \
         ({blocks0} -> {} blocks, version {} -> {})",
        sim.mesh.blocks.len(),
        v0,
        sim.mesh.version
    );
}

#[test]
fn load_balance_shuffle_rebuilds_packs_on_every_rank() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    // 2-rank adaptive run: regrids re-assign blocks across ranks (the
    // load-balance shuffle); every rank's pack cache must track it.
    let deck = common::input_deck("blast", [32, 32, 1], [8, 8, 1], "");
    World::launch(2, move |rank, world| {
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        for ov in amr_overrides() {
            pin.apply_override(ov).unwrap();
        }
        let mut sim = HydroSim::new(pin, rank, world).unwrap();
        let v0 = sim.mesh.version;
        for _ in 0..15 {
            sim.step().unwrap();
            assert!(sim.mesh_data.validate(&sim.mesh).is_ok());
            assert_eq!(sim.mesh_data.built_version(), sim.mesh.version);
            assert_eq!(sim.mesh_data.nblocks(), sim.mesh.blocks.len());
        }
        assert!(sim.mesh.version > v0, "regrids must have shuffled blocks");
    });
}

#[test]
fn staging_survives_same_block_rebuild() {
    // A rebuild that does not change the block set (version bump, fresh
    // containers) must preserve ALL staging: no pack re-gathered, and a
    // scatter restores the exact pre-rebuild data.
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let mut sim = common::single_rank_sim(&deck, &[]);
    sim.step().unwrap();
    let before = common::cons_by_gid(&sim);

    let mut md = MeshData::build(&sim.mesh, 4, None);
    md.gather(&sim.mesh, CONS).unwrap();
    let g0 = md.gathered_packs();
    assert_eq!(g0 as usize, md.npacks(), "initial gather touches every pack");
    assert!(md.dirty_packs().is_empty());

    sim.mesh.rebuild_local_blocks(); // same blocks, zeroed containers
    assert!(md.validate(&sim.mesh).is_err(), "plan is version-stale");
    let kept = md.rebuild_preserving(&sim.mesh, None);
    assert_eq!(kept, md.npacks(), "identical block set keeps every pack");
    assert!(md.validate(&sim.mesh).is_ok());

    md.gather_dirty(&sim.mesh, CONS).unwrap();
    assert_eq!(md.gathered_packs(), g0, "clean packs must not re-gather");

    md.scatter(&mut sim.mesh, CONS).unwrap();
    let after = common::cons_by_gid(&sim);
    assert_eq!(
        common::max_state_diff(&before, &after),
        0.0,
        "resident staging restores the exact state"
    );
}

#[test]
fn device_rebalance_regathers_only_migrated_packs() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    // 2-rank Device run: migrate ONE block between ranks and prove the
    // persistent staging invalidates only the affected packs — the
    // untouched packs are not re-gathered — while the solution stays
    // bitwise identical to an uninterrupted run.
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let dev_ovs = [
        "parthenon/exec/space=device",
        "parthenon/exec/strategy=perpack",
        "parthenon/exec/pack_size=4",
    ];
    let run = |swap: bool| -> Vec<(usize, Vec<f32>)> {
        let results: Arc<Mutex<HashMap<usize, Vec<f32>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let r2 = results.clone();
        let deck = deck.clone();
        World::launch(2, move |rank, world| {
            let mut pin = ParameterInput::from_str(&deck).unwrap();
            for ov in dev_ovs {
                pin.apply_override(ov).unwrap();
            }
            let mut sim = HydroSim::new(pin, rank, world).unwrap();
            for _ in 0..2 {
                sim.step().unwrap();
            }
            if swap {
                let g0 = sim.mesh_data.gathered_packs();
                let npacks_before = sim.mesh_data.npacks() as u64;
                // move the LAST gid (tail of rank 1) to rank 0: packs are
                // contiguous gid runs, so a tail move leaves the leading
                // packs of both ranks untouched (deterministic on both
                // ranks from the shared assignment table)
                let mut new_ranks = sim.mesh.ranks.clone();
                let moved = new_ranks.len() - 1;
                assert_eq!(new_ranks[moved], 1, "Z-order tail lives on rank 1");
                new_ranks[moved] = 0;
                regrid::rebalance(&mut sim, new_ranks).unwrap();
                let delta = sim.mesh_data.gathered_packs() - g0;
                assert!(
                    delta >= 1,
                    "rank {}: migrated packs must re-gather",
                    sim.mesh.my_rank
                );
                assert!(
                    delta < npacks_before.max(sim.mesh_data.npacks() as u64),
                    "rank {}: untouched packs must NOT re-gather (delta {delta})",
                    sim.mesh.my_rank
                );
            }
            for _ in 0..2 {
                sim.step().unwrap();
            }
            sim.sync_device_to_blocks().unwrap();
            let mut res = r2.lock().unwrap();
            for (gid, data) in common::cons_by_gid(&sim) {
                res.insert(gid, data);
            }
        });
        let map = Arc::try_unwrap(results).unwrap().into_inner().unwrap();
        let mut out: Vec<(usize, Vec<f32>)> = map.into_iter().collect();
        out.sort_by_key(|(gid, _)| *gid);
        out
    };
    let base = run(false);
    let swapped = run(true);
    assert_eq!(base.len(), swapped.len());
    assert_eq!(
        common::max_state_diff(&base, &swapped),
        0.0,
        "device rebalance with resident staging must be bitwise transparent"
    );
}

#[test]
fn host_results_independent_of_pack_partition() {
    // Pack-parallel execution must be bitwise identical to the 1-block-per-
    // pack partition: per-block numerics do not depend on pack grouping.
    let deck = common::input_deck("kh", [32, 32, 1], [4, 4, 1], ""); // 64 blocks
    let run = |ps: &str| {
        let mut sim = common::single_rank_sim(&deck, &[ps]);
        for _ in 0..5 {
            sim.step().unwrap();
        }
        common::cons_by_gid(&sim)
    };
    let a = run("parthenon/exec/pack_size=1");
    let b = run("parthenon/exec/pack_size=4");
    let c = run("parthenon/exec/pack_size=16");
    assert_eq!(common::max_state_diff(&a, &b), 0.0, "ps=1 vs ps=4");
    assert_eq!(common::max_state_diff(&b, &c), 0.0, "ps=4 vs ps=16");
}
