//! Incremental delta-plan rebalance vs. the full-rebuild oracle
//! (`parthenon/loadbalance mode=incremental|full`):
//!
//! * regrid-churn on a 2-rank multilevel host mesh must be bitwise
//!   identical between the modes — state, dt bits AND cost EWMAs — across
//!   `sched static/stealing × nworkers 1/4`;
//! * the same identity on the 2-rank Device path, where the incremental
//!   mode must also keep most staging resident (re-gather only the dirty
//!   packs) and migrate only the delta blocks;
//! * a no-op regrid/rebalance must leave every `lb_stats` counter at zero
//!   and re-gather zero packs.

mod common;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use parthenon::comm::World;
use parthenon::config::ParameterInput;
use parthenon::driver::{regrid, EvolutionDriver, HydroSim};
use parthenon::metrics::RebalanceStats;

/// Extra deck block putting one statically refined region in the domain,
/// so the host runs multilevel (prolongation/restriction + flux
/// correction cross the rebalance).
const SMR: &str = "<parthenon/mesh>\nrefinement = static\n\n\
                   <parthenon/static_refinement0>\nlevel = 1\n\
                   x1min = 0.25\nx1max = 0.5\nx2min = 0.25\nx2max = 0.5\n";

/// Deterministic churn assignment: move the head of rank 1's contiguous
/// span to rank 0 and the tail of rank 0's span to rank 1 — blocks leave
/// BOTH ranks, pack boundaries reshape on both, and the map is identical
/// on every rank (derived from the shared tables).
fn churn_assignment(ranks: &[usize]) -> Vec<usize> {
    let mut out = ranks.to_vec();
    let first1 = ranks.iter().position(|&r| r == 1).expect("rank 1 owns blocks");
    assert!(first1 >= 1, "rank 0 must own a tail to trade");
    out[first1] = 0; // head of rank 1 -> rank 0
    out[first1 - 1] = 1; // tail of rank 0 -> rank 1
    out
}

/// One 2-rank churn run: step, force a churn rebalance (with bit-exact
/// sentinel costs planted first), step again, then a second rebalance
/// back. Returns (gid -> interior CONS, dt bits, gid -> cost bits right
/// after the first rebalance, per-rank final lb_stats).
type ChurnResult = (
    Vec<(usize, Vec<f32>)>,
    u64,
    Vec<(usize, u64)>,
    Vec<RebalanceStats>,
);

fn run_churn(deck: String, overrides: Vec<String>, steps: usize) -> ChurnResult {
    let state: Arc<Mutex<HashMap<usize, Vec<f32>>>> = Arc::new(Mutex::new(HashMap::new()));
    let costs: Arc<Mutex<HashMap<usize, u64>>> = Arc::new(Mutex::new(HashMap::new()));
    let dt_bits: Arc<Mutex<u64>> = Arc::new(Mutex::new(0));
    let stats: Arc<Mutex<Vec<RebalanceStats>>> = Arc::new(Mutex::new(vec![
        RebalanceStats::default(),
        RebalanceStats::default(),
    ]));
    let (s2, c2, d2, st2) = (state.clone(), costs.clone(), dt_bits.clone(), stats.clone());
    World::launch(2, move |rank, world| {
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        for ov in &overrides {
            pin.apply_override(ov).unwrap();
        }
        let mut sim = HydroSim::new(pin, rank, world).unwrap();
        for _ in 0..steps {
            sim.step().unwrap();
        }
        // sentinel costs no measurement could produce: survival across the
        // migration must be bit-exact in BOTH modes
        for b in &mut sim.mesh.blocks {
            b.cost = 1.0 + b.gid as f64 * 0.0625;
        }
        let churned = churn_assignment(&sim.mesh.ranks);
        regrid::rebalance(&mut sim, churned).unwrap();
        {
            let mut c = c2.lock().unwrap();
            for b in &sim.mesh.blocks {
                c.insert(b.gid, b.cost.to_bits());
            }
        }
        for _ in 0..steps {
            sim.step().unwrap();
        }
        // churn back the other way (head/tail swapped again)
        let churned = churn_assignment(&sim.mesh.ranks);
        regrid::rebalance(&mut sim, churned).unwrap();
        for _ in 0..steps {
            sim.step().unwrap();
        }
        sim.sync_device_to_blocks().unwrap();
        if rank == 0 {
            *d2.lock().unwrap() = sim.dt.to_bits();
        }
        st2.lock().unwrap()[rank] = sim.lb_stats.clone();
        let mut s = s2.lock().unwrap();
        for (gid, data) in common::cons_by_gid(&sim) {
            s.insert(gid, data);
        }
    });
    let mut out: Vec<(usize, Vec<f32>)> = Arc::try_unwrap(state)
        .unwrap()
        .into_inner()
        .unwrap()
        .into_iter()
        .collect();
    out.sort_by_key(|(gid, _)| *gid);
    let mut cost_bits: Vec<(usize, u64)> = Arc::try_unwrap(costs)
        .unwrap()
        .into_inner()
        .unwrap()
        .into_iter()
        .collect();
    cost_bits.sort_by_key(|(gid, _)| *gid);
    let dt = *dt_bits.lock().unwrap();
    let st = Arc::try_unwrap(stats).unwrap().into_inner().unwrap();
    (out, dt, cost_bits, st)
}

#[test]
fn incremental_matches_full_bitwise_multilevel_host() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    let deck = common::input_deck("blast", [32, 32, 1], [8, 8, 1], SMR);
    let reference = run_churn(
        deck.clone(),
        vec![
            "parthenon/loadbalance/mode=full".into(),
            "parthenon/exec/sched=static".into(),
            "parthenon/exec/nworkers=1".into(),
        ],
        2,
    );
    for sched in ["static", "stealing"] {
        for nw in [1usize, 4] {
            for mode in ["full", "incremental"] {
                if mode == "full" && sched == "static" && nw == 1 {
                    continue; // that IS the reference
                }
                let got = run_churn(
                    deck.clone(),
                    vec![
                        format!("parthenon/loadbalance/mode={mode}"),
                        format!("parthenon/exec/sched={sched}"),
                        format!("parthenon/exec/nworkers={nw}"),
                    ],
                    2,
                );
                let tag = format!("mode={mode} sched={sched} nworkers={nw}");
                assert_eq!(
                    common::max_state_diff(&reference.0, &got.0),
                    0.0,
                    "state must be bitwise identical ({tag})"
                );
                assert_eq!(reference.1, got.1, "dt bits must match ({tag})");
                assert_eq!(
                    reference.2, got.2,
                    "cost EWMAs must survive migration bit-exactly ({tag})"
                );
            }
        }
    }
    // the incremental runs must actually have kept containers in place
    let incr = run_churn(
        deck,
        vec!["parthenon/loadbalance/mode=incremental".into()],
        2,
    );
    for (rank, st) in incr.3.iter().enumerate() {
        assert_eq!(st.rebalances, 2, "rank {rank}: two churn rebalances");
        assert_eq!(st.full_rebuilds, 0, "rank {rank}: no full rebuilds");
        assert_eq!(st.blocks_moved, 4, "rank {rank}: 2 blocks move per churn");
        assert!(
            st.blocks_kept > 0,
            "rank {rank}: staying containers must survive in place"
        );
        assert_eq!(
            st.blocks_sent + st.blocks_received,
            4,
            "rank {rank}: each churn trades one block each way (x2 churns)"
        );
    }
}

#[test]
fn incremental_matches_full_bitwise_device() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let dev_ovs = |mode: &str| {
        vec![
            "parthenon/exec/space=device".to_string(),
            "parthenon/exec/strategy=perpack".to_string(),
            "parthenon/exec/pack_size=4".to_string(),
            format!("parthenon/loadbalance/mode={mode}"),
        ]
    };
    let full = run_churn(deck.clone(), dev_ovs("full"), 2);
    let incr = run_churn(deck, dev_ovs("incremental"), 2);
    assert_eq!(
        common::max_state_diff(&full.0, &incr.0),
        0.0,
        "device incremental rebalance must be bitwise identical to full"
    );
    assert_eq!(full.1, incr.1, "device dt bits must match");
    assert_eq!(full.2, incr.2, "device cost EWMAs must match bit-exactly");
    for (rank, st) in incr.3.iter().enumerate() {
        assert!(
            st.packs_preserved > 0,
            "rank {rank}: some staging must stay resident across the churn"
        );
        assert!(
            st.packs_regathered < 2 * 4,
            "rank {rank}: re-gathers must stay well under packs x rebalances \
             (got {})",
            st.packs_regathered
        );
        assert!(
            st.routes_rebuilt <= st.blocks_received + 2,
            "rank {rank}: only arriving blocks walk the tree for routes"
        );
        assert!(st.bval_segments_resent > 0, "rank {rank}: subset refresh ran");
    }
}

#[test]
fn noop_rebalance_touches_nothing() {
    // single-rank: every assignment is the identity, so both the interval
    // check and an explicit rebalance must be no-ops with zero counters
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let mut sim = common::single_rank_sim(&deck, &[]);
    for _ in 0..2 {
        sim.step().unwrap();
    }
    let gathered0 = sim.mesh_data.gathered_packs();
    let moved = regrid::check_and_rebalance(&mut sim).unwrap();
    assert!(!moved, "single-rank assignment can never change");
    let same = sim.mesh.ranks.clone();
    regrid::rebalance(&mut sim, same).unwrap();
    assert!(
        sim.lb_stats.is_untouched(),
        "a no-op rebalance must migrate 0 blocks and touch no counter: {:?}",
        sim.lb_stats
    );
    assert_eq!(
        sim.mesh_data.gathered_packs(),
        gathered0,
        "a no-op rebalance must re-gather 0 packs"
    );
}

#[test]
fn noop_regrid_stable_tree_two_ranks() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    // 2-rank: equal sentinel costs on every block reproduce the seed
    // assignment exactly, so check_and_rebalance finds nothing to move —
    // and must leave every counter untouched on BOTH ranks.
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    World::launch(2, move |rank, world| {
        let pin = ParameterInput::from_str(&deck).unwrap();
        let mut sim = HydroSim::new(pin, rank, world).unwrap();
        for _ in 0..2 {
            sim.step().unwrap();
        }
        for b in &mut sim.mesh.blocks {
            b.cost = 1.0;
        }
        let gathered0 = sim.mesh_data.gathered_packs();
        let moved = regrid::check_and_rebalance(&mut sim).unwrap();
        assert!(!moved, "rank {rank}: equal costs keep the seed assignment");
        assert!(
            sim.lb_stats.is_untouched(),
            "rank {rank}: stable-tree regrid must migrate 0 blocks: {:?}",
            sim.lb_stats
        );
        assert_eq!(
            sim.mesh_data.gathered_packs(),
            gathered0,
            "rank {rank}: stable-tree regrid must re-gather 0 packs"
        );
    });
}

#[test]
fn full_swap_still_works_incrementally() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    // Degenerate delta = everything: the incremental path must handle a
    // complete ownership swap (no block survives in place on either rank).
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let run = |swap: bool| {
        let state: Arc<Mutex<HashMap<usize, Vec<f32>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let s2 = state.clone();
        let deck = deck.clone();
        World::launch(2, move |rank, world| {
            let pin = ParameterInput::from_str(&deck).unwrap();
            let mut sim = HydroSim::new(pin, rank, world).unwrap();
            for _ in 0..3 {
                sim.step().unwrap();
            }
            if swap {
                let new_ranks: Vec<usize> =
                    sim.mesh.ranks.iter().map(|r| 1 - *r).collect();
                regrid::rebalance(&mut sim, new_ranks).unwrap();
                assert_eq!(sim.lb_stats.blocks_kept, 0, "nothing stays in a swap");
                assert_eq!(sim.lb_stats.blocks_moved, 16);
            }
            for _ in 0..3 {
                sim.step().unwrap();
            }
            let mut s = s2.lock().unwrap();
            for (gid, data) in common::cons_by_gid(&sim) {
                s.insert(gid, data);
            }
        });
        let mut out: Vec<(usize, Vec<f32>)> = Arc::try_unwrap(state)
            .unwrap()
            .into_inner()
            .unwrap()
            .into_iter()
            .collect();
        out.sort_by_key(|(gid, _)| *gid);
        out
    };
    let base = run(false);
    let swapped = run(true);
    assert_eq!(
        common::max_state_diff(&base, &swapped),
        0.0,
        "a full-swap incremental rebalance must be bitwise transparent"
    );
}
