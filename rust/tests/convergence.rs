//! Linear-wave convergence: the RK2 + PLM + HLLE scheme must converge at
//! close to second order on a smooth acoustic wave (the paper's/ATHENA++'s
//! canonical correctness test, Sec. 4.1).

mod common;

use parthenon::driver::EvolutionDriver;
use parthenon::hydro::problems::linear_wave_exact;
use parthenon::hydro::CONS;

/// L1 density error against the exact (linearized) translated wave after
/// time t, on a 1D mesh of nx cells.
fn l1_error(nx: usize, t_end: f64) -> f64 {
    let deck = common::input_deck("linear_wave", [nx, 1, 1], [nx / 2, 1, 1], "");
    let mut sim = common::single_rank_sim(
        &deck,
        &["hydro/cfl=0.3", "hydro/gamma=1.4"],
    );
    while sim.time < t_end {
        if sim.time + sim.dt > t_end {
            sim.dt = t_end - sim.time;
        }
        sim.step().unwrap();
    }
    let gamma = 1.4f32;
    let p0 = 1.0 / 1.4f32;
    let shape = sim.mesh.cfg.index_shape();
    let mut err = 0.0f64;
    let mut cells = 0usize;
    for b in &sim.mesh.blocks {
        let arr = b.data.get(CONS).unwrap();
        for i in shape.is_(0)..shape.ie(0) {
            let x = b.coords.center(0, i);
            let exact = linear_wave_exact(x, t_end, gamma, 1e-3, 1.0, p0, 1.0);
            let got = arr.as_slice()[shape.idx3(0, 0, i)];
            err += (got - exact[0]).abs() as f64;
            cells += 1;
        }
    }
    err / cells as f64
}

#[test]
fn linear_wave_converges_near_second_order() {
    // One wave period: cs = sqrt(gamma * p0 / rho0) = 1 -> t = wavelength.
    //
    // NOTE: the hot path is f32 (matching the AOT artifact dtype), so the
    // comparison against the *linearized* exact solution hits a floor of
    // O(amplitude^2) + f32 roundoff accumulation around ~2e-6; with the
    // HLLE solver the asymptotic order on coarse grids is between 1.5 and
    // 2.  We assert a decreasing error sequence with order > 1.3 across
    // 16 -> 32 -> 64 (the regime above the floor); examples/linear_wave.rs
    // prints the full table.
    let t = 1.0;
    let e16 = l1_error(16, t);
    let e32 = l1_error(32, t);
    let e64 = l1_error(64, t);
    let order_lo = (e16 / e32).log2();
    let order_hi = (e32 / e64).log2();
    eprintln!("L1 errors: {e16:.3e} {e32:.3e} {e64:.3e}; orders {order_lo:.2} {order_hi:.2}");
    assert!(e32 < e16 && e64 < e32, "errors must decrease");
    assert!(
        order_lo > 1.3 && order_hi > 1.3,
        "convergence order too low: {order_lo:.2}, {order_hi:.2}"
    );
}

#[test]
fn wave_amplitude_is_preserved() {
    // after one period the wave must not have decayed catastrophically
    let deck = common::input_deck("linear_wave", [64, 1, 1], [64, 1, 1], "");
    let mut sim = common::single_rank_sim(&deck, &[]);
    let t_end = 1.0;
    while sim.time < t_end {
        if sim.time + sim.dt > t_end {
            sim.dt = t_end - sim.time;
        }
        sim.step().unwrap();
    }
    let shape = sim.mesh.cfg.index_shape();
    let mut max_drho = 0.0f32;
    for b in &sim.mesh.blocks {
        let arr = b.data.get(CONS).unwrap();
        for i in shape.is_(0)..shape.ie(0) {
            max_drho = max_drho.max((arr.as_slice()[shape.idx3(0, 0, i)] - 1.0).abs());
        }
    }
    assert!(
        max_drho > 0.5e-3,
        "wave decayed too much: amplitude {max_drho:.2e} of 1e-3"
    );
}
