//! Adaptive mesh refinement: regrid correctness, conservation through
//! refinement/derefinement, nesting invariants, load-balance migration.

mod common;

use parthenon::comm::{ReduceOp, World};
use parthenon::config::ParameterInput;
use parthenon::driver::{EvolutionDriver, HydroSim};
use parthenon::hydro::CONS;

fn amr_deck(problem: &str) -> String {
    let base = common::input_deck(problem, [32, 32, 1], [8, 8, 1], "");
    base.replace(
        "<parthenon/time>",
        "<parthenon/mesh_amr>\nx = 1\n\n<parthenon/time>",
    ) + "\n"
}

fn amr_overrides() -> Vec<&'static str> {
    vec![
        "parthenon/mesh/refinement=adaptive",
        "parthenon/mesh/numlevel=2",
        "parthenon/mesh/check_refine_interval=3",
        "hydro/refine_criterion=pressure_gradient",
        "hydro/refine_tol=0.25",
        "hydro/derefine_tol=0.03",
    ]
}

#[test]
fn amr_run_refines_and_conserves() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    World::launch(2, |rank, world| {
        let mut pin = ParameterInput::from_str(&amr_deck("blast")).unwrap();
        for ov in amr_overrides() {
            pin.apply_override(ov).unwrap();
        }
        let mut sim = HydroSim::new(pin, rank, world.clone()).unwrap();
        let comm = world.comm(rank, 0);
        let before = comm.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        let initial_blocks = sim.mesh.tree.nblocks();
        let mut max_blocks = initial_blocks;
        for _ in 0..30 {
            sim.step().unwrap();
            max_blocks = max_blocks.max(sim.mesh.tree.nblocks());
            assert!(sim.mesh.tree.is_properly_nested());
            assert!(sim.mesh.tree.check_coverage().is_ok());
        }
        let after = comm.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        assert!(
            max_blocks > initial_blocks,
            "blast must trigger refinement ({initial_blocks} -> {max_blocks})"
        );
        for idx in [0usize, 3usize] {
            let rel = ((after[idx] - before[idx]) / before[idx]).abs();
            assert!(
                rel < 1e-4,
                "quantity {idx} drifted {rel:.2e} under AMR"
            );
        }
        // every local block has data consistent with its gid
        for b in &sim.mesh.blocks {
            assert_eq!(sim.mesh.ranks[b.gid], rank);
        }
    });
}

#[test]
fn regrid_balances_blocks_across_ranks() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    World::launch(4, |rank, world| {
        let mut pin = ParameterInput::from_str(&amr_deck("blast")).unwrap();
        for ov in amr_overrides() {
            pin.apply_override(ov).unwrap();
        }
        let mut sim = HydroSim::new(pin, rank, world.clone()).unwrap();
        for _ in 0..12 {
            sim.step().unwrap();
        }
        let comm = world.comm(rank, 0);
        let nblocks = sim.mesh.tree.nblocks() as f64;
        let local = sim.mesh.num_local_blocks() as f64;
        let max = comm.allreduce(local, ReduceOp::Max);
        let min = comm.allreduce(local, ReduceOp::Min);
        assert!(
            max - min <= (nblocks / 4.0).ceil(),
            "load imbalance: min {min} max {max} of {nblocks}"
        );
        // all ranks agree on the tree
        let leaves = sim.mesh.tree.nblocks() as f64;
        let same = comm.allreduce(leaves, ReduceOp::Max);
        assert_eq!(same, leaves);
    });
}

#[test]
fn refine_then_derefine_restores_smooth_state() {
    // a smooth state should not stay refined: run blast until the wave
    // leaves a region, ensure derefinement happens at some point
    let mut pin = ParameterInput::from_str(&amr_deck("blast")).unwrap();
    for ov in amr_overrides() {
        pin.apply_override(ov).unwrap();
    }
    pin.apply_override("problem/p_in=2.0").unwrap(); // weak blast decays
    let world = World::new(1);
    let mut sim = HydroSim::new(pin, 0, world).unwrap();
    let mut counts = Vec::new();
    for _ in 0..40 {
        sim.step().unwrap();
        counts.push(sim.mesh.tree.nblocks());
    }
    let peak = *counts.iter().max().unwrap();
    assert!(peak >= counts[0], "refinement expected");
    // interior state stays positive through all the regrids
    let shape = sim.mesh.cfg.index_shape();
    for b in &sim.mesh.blocks {
        let arr = b.data.get(CONS).unwrap();
        for j in shape.is_(1)..shape.ie(1) {
            for i in shape.is_(0)..shape.ie(0) {
                assert!(arr.as_slice()[shape.idx3(0, j, i)] > 0.0);
            }
        }
    }
}
