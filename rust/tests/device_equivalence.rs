//! Host (native Rust) vs Device (PJRT artifacts) equivalence: the same
//! problem advanced N cycles on both execution spaces must agree to f32
//! tolerance — the cross-layer correctness pin of the whole stack.
//!
//! The uniform fast-path comparisons are tolerance-based (the fused
//! artifact stages ghosts differently, so limiter switching amplifies f32
//! noise). The general-mode tests at the bottom are BITWISE: on a
//! multilevel or non-periodic mesh the Device path launches the same
//! per-block kernels on the same bytes as the host sweep, so the final
//! state, the dt bits, and the checkpoint bytes must be identical.

mod common;

use parthenon::driver::EvolutionDriver;

fn run_n(deck: &str, overrides: &[&str], ncycles: usize) -> (Vec<(usize, Vec<f32>)>, f64) {
    let mut sim = common::single_rank_sim(deck, overrides);
    for _ in 0..ncycles {
        sim.step().unwrap();
    }
    sim.sync_device_to_blocks().unwrap();
    (common::cons_by_gid(&sim), sim.time)
}

#[test]
fn host_vs_device_perpack_2d() {
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // smooth problem: truncation-level agreement holds over many cycles
    let deck = common::input_deck("kh", [64, 64, 1], [32, 32, 1], "");
    let (host, th) = run_n(&deck, &[], 8);
    let (dev, td) = run_n(
        &deck,
        &["parthenon/exec/space=device", "parthenon/exec/strategy=perpack"],
        8,
    );
    assert!((th - td).abs() < 1e-6 * th.abs().max(1.0), "time {th} vs {td}");
    let diff = common::max_state_diff(&host, &dev);
    assert!(diff < 1e-3, "host vs device diff {diff}");

    // shock problem: nonlinear limiter switching amplifies f32 noise, so
    // compare after a short horizon only
    let deck_b = common::input_deck("blast", [64, 64, 1], [32, 32, 1], "");
    let (host_b, _) = run_n(&deck_b, &[], 2);
    let (dev_b, _) = run_n(
        &deck_b,
        &["parthenon/exec/space=device", "parthenon/exec/strategy=perpack"],
        2,
    );
    // At the initial pressure discontinuity the MC limiter's branch is
    // bit-fragile (product test at exactly zero), so pointwise agreement is
    // O(1) on the jump ring; assert instead that the disagreement is
    // *localized* (small L1) and that the conserved integrals match.
    let (l1, nbig) = l1_and_count(&host_b, &dev_b, 1e-3);
    assert!(l1 < 5e-4, "blast L1/N diff {l1}");
    assert!(nbig < 600, "blast: too many differing cells: {nbig}");
    let (sh, sd) = (global_sums(&host_b), global_sums(&dev_b));
    for v in 0..5 {
        let rel = ((sh[v] - sd[v]) / sh[v].abs().max(1.0)).abs();
        assert!(rel < 1e-5, "conserved sum {v} drifted {rel:.2e}");
    }
}

#[test]
fn strategies_agree_with_each_other_3d() {
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let deck = common::input_deck("blast", [16, 16, 16], [8, 8, 8], "");
    let (perpack, _) = run_n(
        &deck,
        &["parthenon/exec/space=device", "parthenon/exec/strategy=perpack"],
        3,
    );
    let (perblock, _) = run_n(
        &deck,
        &["parthenon/exec/space=device", "parthenon/exec/strategy=perblock"],
        3,
    );
    let (perbuffer, _) = run_n(
        &deck,
        &["parthenon/exec/space=device", "parthenon/exec/strategy=perbuffer"],
        3,
    );
    let d1 = common::max_state_diff(&perpack, &perblock);
    let d2 = common::max_state_diff(&perblock, &perbuffer);
    assert!(d1 < 1e-5, "perpack vs perblock {d1}");
    assert!(d2 < 1e-5, "perblock vs perbuffer {d2}");
}

#[test]
fn host_vs_device_3d_multirank() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use parthenon::comm::World;
    use parthenon::config::ParameterInput;
    use parthenon::driver::HydroSim;
    use std::sync::{Arc, Mutex};

    let deck = common::input_deck("blast", [16, 16, 16], [8, 8, 8], "");
    let run = |overrides: Vec<String>| -> Vec<(usize, Vec<f32>)> {
        let out: Arc<Mutex<Vec<(usize, Vec<f32>)>>> = Arc::new(Mutex::new(Vec::new()));
        let o2 = out.clone();
        let deck = deck.clone();
        World::launch(2, move |rank, world| {
            let mut pin = ParameterInput::from_str(&deck).unwrap();
            for ov in &overrides {
                pin.apply_override(ov).unwrap();
            }
            let mut sim = HydroSim::new(pin, rank, world).unwrap();
            for _ in 0..4 {
                sim.step().unwrap();
            }
            sim.sync_device_to_blocks().unwrap();
            let mut blocks = common::cons_by_gid(&sim);
            o2.lock().unwrap().append(&mut blocks);
        });
        let mut v = Arc::try_unwrap(out).unwrap().into_inner().unwrap();
        v.sort_by_key(|(gid, _)| *gid);
        v
    };
    let host = run(vec![]);
    let dev = run(vec![
        "parthenon/exec/space=device".into(),
        "parthenon/exec/strategy=perpack".into(),
        "parthenon/exec/pack_size=4".into(),
    ]);
    // 3D blast: shock-adjacent limiter switching makes pointwise compares
    // meaningless; assert localized L1 + matching conserved integrals
    let (l1, _) = l1_and_count(&host, &dev, 1e-3);
    assert!(l1 < 1e-3, "3D blast L1/N diff {l1}");
    let (sh, sd) = (global_sums(&host), global_sums(&dev));
    for v in 0..5 {
        let rel = ((sh[v] - sd[v]) / sh[v].abs().max(1.0)).abs();
        assert!(rel < 1e-5, "conserved sum {v} drifted {rel:.2e}");
    }
}


/// Run single-rank for `steps`; return (gid -> interior CONS, dt bits,
/// restart-file bytes) — the bitwise-comparison triple for the
/// general-mode tests below.
fn run_bitwise(
    deck: &str,
    overrides: &[String],
    steps: usize,
    tag: &str,
) -> (Vec<(usize, Vec<f32>)>, u64, Vec<u8>) {
    let ovs: Vec<&str> = overrides.iter().map(|s| s.as_str()).collect();
    let mut sim = common::single_rank_sim(deck, &ovs);
    for _ in 0..steps {
        sim.step().unwrap();
    }
    let tmp = std::env::temp_dir().join(format!("parthenon_dev_eq_{tag}.pbin"));
    let tmp_s = tmp.to_str().unwrap().to_string();
    sim.write_restart(&tmp_s).unwrap(); // syncs device staging back first
    let bytes = std::fs::read(&tmp).unwrap();
    let _ = std::fs::remove_file(&tmp);
    (common::cons_by_gid(&sim), sim.dt.to_bits(), bytes)
}

fn assert_bitwise(
    tag: &str,
    base: &(Vec<(usize, Vec<f32>)>, u64, Vec<u8>),
    got: &(Vec<(usize, Vec<f32>)>, u64, Vec<u8>),
) {
    assert_eq!(
        common::max_state_diff(&base.0, &got.0),
        0.0,
        "{tag}: final state must be bitwise identical"
    );
    assert_eq!(got.1, base.1, "{tag}: dt bits must be identical");
    assert_eq!(got.2, base.2, "{tag}: checkpoint bytes must be identical");
}

/// Static-refinement overrides: a level-1 cube over the domain center, the
/// same SMR shape as `hybrid_equivalence` and the fig11 perf lane.
fn ml_overrides() -> Vec<String> {
    [
        "parthenon/mesh/refinement=static",
        "parthenon/mesh/numlevel=2",
        "parthenon/static_refinement0/level=1",
        "parthenon/static_refinement0/x1min=0.3",
        "parthenon/static_refinement0/x1max=0.7",
        "parthenon/static_refinement0/x2min=0.3",
        "parthenon/static_refinement0/x2max=0.7",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

#[test]
fn host_vs_device_multilevel_bitwise() {
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Multilevel SMR: the general-mode Device path (per-block launches,
    // restrict/prolong ghost segments, flux correction at the level seam)
    // must be bitwise the host path — same kernels, same bytes.
    let deck = common::input_deck("blast", [16, 16, 1], [4, 4, 1], "");
    let ml = ml_overrides();
    for sched in ["static", "stealing"] {
        for nw in [1usize, 4] {
            let mut bo = vec![
                format!("parthenon/exec/sched={sched}"),
                format!("parthenon/exec/nworkers={nw}"),
                "parthenon/exec/pack_size=2".to_string(),
            ];
            bo.extend(ml.iter().cloned());
            let base = run_bitwise(&deck, &bo, 3, "mldev_base");
            let mut dvo = bo.clone();
            dvo.push("parthenon/exec/space=device".into());
            let dev = run_bitwise(&deck, &dvo, 3, "mldev_dev");
            assert_bitwise(
                &format!("multilevel device vs host sched={sched} nw={nw}"),
                &base,
                &dev,
            );
        }
    }
}

#[test]
fn host_vs_hybrid_multilevel_bitwise() {
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // True co-execution on the multilevel mesh: a forced 50/50 split runs
    // half the packs on each space in ONE TaskRegion, and the result must
    // still be bitwise the host run — the general-mode parity claim, not
    // just the degenerate split=0 endpoint.
    let deck = common::input_deck("blast", [16, 16, 1], [4, 4, 1], "");
    let ml = ml_overrides();
    for nw in [1usize, 4] {
        let mut bo = vec![
            format!("parthenon/exec/nworkers={nw}"),
            "parthenon/exec/sched=stealing".to_string(),
            "parthenon/exec/pack_size=2".to_string(),
        ];
        bo.extend(ml.iter().cloned());
        let base = run_bitwise(&deck, &bo, 3, "mlhyb_base");
        let mut ho = bo.clone();
        ho.push("parthenon/exec/space=hybrid".into());
        ho.push("parthenon/exec/hybrid_split=0.5".into());
        let hyb = run_bitwise(&deck, &ho, 3, "mlhyb_hyb");
        assert_bitwise(&format!("multilevel hybrid 0.5 vs host nw={nw}"), &base, &hyb);
    }
}

#[test]
fn host_vs_device_nonperiodic_bitwise() {
    if !common::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Non-periodic physical boundaries on a uniform mesh also route
    // through general mode (the fast path is periodic-only): the per-pack
    // BC fill at poll-drain must be bitwise the host's global sweep.
    let deck = common::input_deck("blast", [16, 16, 1], [8, 8, 1], "");
    let bo = vec![
        "parthenon/exec/pack_size=2".to_string(),
        "parthenon/mesh/ix1_bc=outflow".to_string(),
        "parthenon/mesh/ox1_bc=reflecting".to_string(),
        "parthenon/mesh/ix2_bc=outflow".to_string(),
        "parthenon/mesh/ox2_bc=outflow".to_string(),
    ];
    let base = run_bitwise(&deck, &bo, 3, "npdev_base");
    let mut dvo = bo.clone();
    dvo.push("parthenon/exec/space=device".into());
    let dev = run_bitwise(&deck, &dvo, 3, "npdev_dev");
    assert_bitwise("non-periodic device vs host", &base, &dev);
}

/// (mean |a-b|, count of cells with |a-b| > thresh).
fn l1_and_count(a: &[(usize, Vec<f32>)], b: &[(usize, Vec<f32>)], thresh: f32) -> (f64, usize) {
    let mut l1 = 0.0f64;
    let mut n = 0usize;
    let mut big = 0usize;
    for ((_, va), (_, vb)) in a.iter().zip(b.iter()) {
        for (x, y) in va.iter().zip(vb.iter()) {
            let d = (x - y).abs();
            l1 += d as f64;
            n += 1;
            if d > thresh {
                big += 1;
            }
        }
    }
    (l1 / n as f64, big)
}

/// Per-variable global sums (over the WHOLE ghosted arrays — fine for a
/// relative comparison).
fn global_sums(a: &[(usize, Vec<f32>)]) -> [f64; 5] {
    let mut out = [0.0f64; 5];
    for (_, v) in a {
        let n = v.len() / 5;
        for c in 0..5 {
            for x in &v[c * n..(c + 1) * n] {
                out[c] += *x as f64;
            }
        }
    }
    out
}
