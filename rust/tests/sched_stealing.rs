//! Scheduler determinism: the work-stealing executor decides only WHERE a
//! pack runs, never what it computes — results must be bitwise identical
//! to the static scheduler for every worker count and every forced steal
//! order, on uniform and multilevel meshes, and across a cost-driven
//! load balance.

mod common;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use parthenon::comm::World;
use parthenon::config::ParameterInput;
use parthenon::driver::{regrid, EvolutionDriver, HydroSim};

/// Run `deck` single-rank for `steps` with the given overrides; return
/// gid -> interior CONS.
fn run_host(deck: &str, overrides: &[&str], steps: usize) -> Vec<(usize, Vec<f32>)> {
    let mut sim = common::single_rank_sim(deck, overrides);
    for _ in 0..steps {
        sim.step().unwrap();
    }
    common::cons_by_gid(&sim)
}

#[test]
fn stealing_matches_static_across_worker_counts() {
    // 64 blocks, pack_size 4 -> 16 packs: plenty to deal and steal.
    let deck = common::input_deck("kh", [32, 32, 1], [4, 4, 1], "");
    let base = run_host(
        &deck,
        &[
            "parthenon/exec/sched=static",
            "parthenon/exec/nworkers=1",
            "parthenon/exec/pack_size=4",
        ],
        5,
    );
    for nw in [1usize, 2, 4, 8] {
        let ov = format!("parthenon/exec/nworkers={nw}");
        let got = run_host(
            &deck,
            &[
                "parthenon/exec/sched=stealing",
                "parthenon/exec/pack_size=4",
                &ov,
            ],
            5,
        );
        assert_eq!(
            common::max_state_diff(&base, &got),
            0.0,
            "stealing nworkers={nw} must be bitwise identical to static"
        );
    }
}

#[test]
fn forced_steal_orders_are_bitwise_identical() {
    let deck = common::input_deck("kh", [32, 32, 1], [4, 4, 1], "");
    let base = run_host(
        &deck,
        &[
            "parthenon/exec/sched=static",
            "parthenon/exec/nworkers=4",
            "parthenon/exec/pack_size=4",
        ],
        5,
    );
    for sched in ["stealing", "roundrobin", "reverse"] {
        let ov = format!("parthenon/exec/sched={sched}");
        let got = run_host(
            &deck,
            &[&ov, "parthenon/exec/nworkers=4", "parthenon/exec/pack_size=4"],
            5,
        );
        assert_eq!(
            common::max_state_diff(&base, &got),
            0.0,
            "steal order {sched} must not change results"
        );
    }
}

#[test]
fn multilevel_stealing_matches_static() {
    // Static refinement -> multilevel: flux correction + prolongation +
    // the parallel exchange path are all live.
    let deck = common::input_deck("blast", [32, 32, 1], [8, 8, 1], "");
    let ml = [
        "parthenon/mesh/refinement=static",
        "parthenon/mesh/numlevel=2",
        "parthenon/static_refinement0/level=1",
        "parthenon/static_refinement0/x1min=0.3",
        "parthenon/static_refinement0/x1max=0.7",
        "parthenon/static_refinement0/x2min=0.3",
        "parthenon/static_refinement0/x2max=0.7",
        "parthenon/exec/pack_size=2",
    ];
    let mut base_ov: Vec<&str> = ml.to_vec();
    base_ov.push("parthenon/exec/sched=static");
    base_ov.push("parthenon/exec/nworkers=1");
    let base = run_host(&deck, &base_ov, 4);
    assert!(base.len() > 16, "refinement must have produced extra blocks");
    for nw in [2usize, 4] {
        let ov = format!("parthenon/exec/nworkers={nw}");
        let mut got_ov: Vec<&str> = ml.to_vec();
        got_ov.push("parthenon/exec/sched=stealing");
        got_ov.push(&ov);
        let got = run_host(&deck, &got_ov, 4);
        assert_eq!(
            common::max_state_diff(&base, &got),
            0.0,
            "multilevel stealing nworkers={nw}"
        );
    }
}

#[test]
fn measured_costs_feed_block_weights() {
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let mut sim = common::single_rank_sim(&deck, &[]);
    for _ in 0..3 {
        sim.step().unwrap();
    }
    // EWMA must have moved at least some blocks off the nominal weight,
    // and the rank-local mean must stay ~1 (normalized samples).
    let costs: Vec<f64> = sim.mesh.blocks.iter().map(|b| b.cost).collect();
    assert!(
        costs.iter().any(|c| (c - 1.0).abs() > 1e-9),
        "measured timings must update MeshBlock::cost"
    );
    let mean = costs.iter().sum::<f64>() / costs.len() as f64;
    assert!(
        (mean - 1.0).abs() < 0.5,
        "normalized cost mean should stay near 1, got {mean}"
    );
    assert!(costs.iter().all(|c| *c > 0.0));
}

/// Run a 2-rank host simulation; optionally force a full rank-swap
/// rebalance after `swap_at` steps. Returns gid -> interior CONS.
fn run_two_rank(deck: String, steps: usize, swap_at: Option<usize>) -> Vec<(usize, Vec<f32>)> {
    let results: Arc<Mutex<HashMap<usize, Vec<f32>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let r2 = results.clone();
    World::launch(2, move |rank, world| {
        let pin = ParameterInput::from_str(&deck).unwrap();
        let mut sim = HydroSim::new(pin, rank, world).unwrap();
        for s in 0..steps {
            sim.step().unwrap();
            if Some(s + 1) == swap_at {
                // deterministic on both ranks: swap every block's owner
                let new_ranks: Vec<usize> =
                    sim.mesh.ranks.iter().map(|r| 1 - *r).collect();
                regrid::rebalance(&mut sim, new_ranks).unwrap();
            }
        }
        let mut res = r2.lock().unwrap();
        for (gid, data) in common::cons_by_gid(&sim) {
            res.insert(gid, data);
        }
    });
    let map = Arc::try_unwrap(results).unwrap().into_inner().unwrap();
    let mut out: Vec<(usize, Vec<f32>)> = map.into_iter().collect();
    out.sort_by_key(|(gid, _)| *gid);
    out
}

#[test]
fn rebalance_midrun_is_bitwise_transparent() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    let deck = common::input_deck("kh", [32, 32, 1], [8, 8, 1], "");
    let base = run_two_rank(deck.clone(), 6, None);
    let swapped = run_two_rank(deck, 6, Some(3));
    assert_eq!(base.len(), swapped.len());
    assert_eq!(
        common::max_state_diff(&base, &swapped),
        0.0,
        "a fixed-tree rebalance must not change the solution"
    );
}
