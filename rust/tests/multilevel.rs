//! Multilevel (SMR) correctness: prolongation/restriction in the ghost
//! exchange and conservation through flux correction.

mod common;

use parthenon::bvals;
use parthenon::comm::{tags, ReduceOp, World};
use parthenon::config::ParameterInput;
use parthenon::driver::{EvolutionDriver, HydroSim};
use parthenon::hydro::CONS;

fn smr_deck(problem: &str) -> String {
    common::input_deck(
        problem,
        [32, 32, 1],
        [8, 8, 1],
        "\n<parthenon/mesh_extra>\nx = 1\n",
    )
    .replace(
        "<parthenon/time>",
        "<parthenon/static_refinement0>\nlevel = 1\nx1min = 0.4\nx1max = 0.6\n\
         x2min = 0.4\nx2max = 0.6\n\n<parthenon/time>",
    )
}

/// Fill CONS with a function of physical position.
fn paint(sim: &mut HydroSim, f: impl Fn(usize, f64, f64) -> f32) {
    let shape = sim.mesh.cfg.index_shape();
    let n = shape.ncells_total();
    for b in &mut sim.mesh.blocks {
        let coords = b.coords;
        let arr = b.data.get_mut(CONS).unwrap();
        for v in 0..5 {
            for j in 0..shape.nt(1) {
                for i in 0..shape.nt(0) {
                    let x = coords.center(0, i);
                    let y = coords.center(1, j);
                    arr.as_mut_slice()[v * n + shape.idx3(0, j, i)] = f(v, x, y);
                }
            }
        }
    }
}

#[test]
fn smr_mesh_has_levels_and_nests() {
    let sim = common::single_rank_sim(&smr_deck("uniform"), &[]);
    assert_eq!(sim.mesh.tree.max_level(), 1);
    assert!(sim.mesh.tree.is_properly_nested());
    assert!(sim.mesh.tree.check_coverage().is_ok());
    assert!(sim.mesh.tree.nblocks() > 16);
}

#[test]
fn constant_field_exact_across_levels() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    World::launch(2, |rank, world| {
        let pin = ParameterInput::from_str(&smr_deck("uniform")).unwrap();
        let mut sim = HydroSim::new(pin, rank, world.clone()).unwrap();
        paint(&mut sim, |v, _, _| 1.0 + v as f32);
        let comm = world.comm(rank, tags::COMM_BVALS_BASE);
        bvals::exchange_blocking(&mut sim.mesh, &comm, CONS, None).unwrap();
        let shape = sim.mesh.cfg.index_shape();
        let n = shape.ncells_total();
        for b in &sim.mesh.blocks {
            let arr = b.data.get(CONS).unwrap();
            for v in 0..5 {
                for j in 0..shape.nt(1) {
                    for i in 0..shape.nt(0) {
                        let got = arr.as_slice()[v * n + shape.idx3(0, j, i)];
                        assert!(
                            (got - (1.0 + v as f32)).abs() < 1e-6,
                            "gid {} v{v} ({j},{i}): {got}",
                            b.gid
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn coarse_ghosts_from_fine_are_exact_for_linear() {
    // restriction (averaging) reproduces linear fields exactly, so every
    // coarse ghost filled from finer neighbors must match f = a*x + b*y.
    let mut sim = common::single_rank_sim(&smr_deck("uniform"), &[]);
    paint(&mut sim, |_, x, y| (3.0 * x + 2.0 * y) as f32);
    let world = World::new(1);
    let comm = world.comm(0, tags::COMM_BVALS_BASE);
    // NB: sim was built on its own world; reuse its comm id space is fine
    // for a single rank.
    bvals::exchange_blocking(&mut sim.mesh, &comm, CONS, None).unwrap();
    let shape = sim.mesh.cfg.index_shape();
    let n = shape.ncells_total();
    let tree = sim.mesh.tree.clone();
    for b in &sim.mesh.blocks {
        // coarse blocks (level 0) adjacent to fine: check their ghost zones
        if b.loc.level != 0 {
            continue;
        }
        for nb in tree.find_neighbors(&b.loc) {
            if !matches!(nb.kind, parthenon::mesh::NeighborKind::Finer(_)) {
                continue;
            }
            // skip slabs that wrap the periodic boundary: the linear test
            // field is not periodic, so wrapped ghosts legitimately differ
            let w0 = sim.mesh.cfg.nrb[0] << b.loc.level;
            let w1 = sim.mesh.cfg.nrb[1] << b.loc.level;
            let nx0 = b.loc.lx[0] + nb.offset[0] as i64;
            let nx1 = b.loc.lx[1] + nb.offset[1] as i64;
            if nx0 < 0 || nx0 >= w0 || nx1 < 0 || nx1 >= w1 {
                continue;
            }
            let slab = parthenon_recv_slab(nb.offset, &shape);
            let arr = b.data.get(CONS).unwrap();
            for j in slab.1 .0..slab.1 .1 {
                for i in slab.0 .0..slab.0 .1 {
                    let x = b.coords.center(0, i);
                    let y = b.coords.center(1, j);
                    let expect = (3.0 * x + 2.0 * y) as f32;
                    let got = arr.as_slice()[shape.idx3(0, j, i)];
                    assert!(
                        (got - expect).abs() < 1e-4,
                        "gid {} ({j},{i}): {got} vs {expect}",
                        b.gid
                    );
                }
            }
        }
    }
}

// small local mirror of bufspec::recv_slab (x/y ranges only)
fn parthenon_recv_slab(
    offset: [i32; 3],
    shape: &parthenon::mesh::IndexShape,
) -> ((usize, usize), (usize, usize)) {
    let g = parthenon::NGHOST;
    let ax = |o: i32, n: usize| match o {
        -1 => (0, g),
        1 => (g + n, 2 * g + n),
        _ => (g, g + n),
    };
    (ax(offset[0], shape.n[0]), ax(offset[1], shape.n[1]))
}

#[test]
fn conservation_on_multilevel_mesh_with_flux_correction() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    // blast crossing the refinement boundary: total mass and energy must be
    // conserved to f32 roundoff accumulation thanks to flux correction.
    World::launch(2, |rank, world| {
        let mut pin = ParameterInput::from_str(&smr_deck("blast")).unwrap();
        pin.set("problem", "radius", 0.25); // big enough to cross levels
        pin.apply_override("parthenon/time/nlim=25").unwrap();
        let mut sim = HydroSim::new(pin, rank, world.clone()).unwrap();
        let comm = world.comm(rank, 0);
        let before = comm.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        for _ in 0..25 {
            sim.step().unwrap();
        }
        let after = comm.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        // mass and total energy
        for idx in [0usize, 3usize] {
            let rel = ((after[idx] - before[idx]) / before[idx]).abs();
            assert!(
                rel < 5e-5,
                "quantity {idx} drifted: {} -> {} (rel {rel:.2e})",
                before[idx],
                after[idx]
            );
        }
        assert!(sim.time > 0.0);
    });
}

#[test]
fn multilevel_blast_stays_finite_and_positive() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    World::launch(2, |rank, world| {
        let pin = ParameterInput::from_str(&smr_deck("blast")).unwrap();
        let mut sim = HydroSim::new(pin, rank, world).unwrap();
        for _ in 0..15 {
            sim.step().unwrap();
        }
        let shape = sim.mesh.cfg.index_shape();
        let n = shape.ncells_total();
        for b in &sim.mesh.blocks {
            let arr = b.data.get(CONS).unwrap();
            for k in shape.is_(2)..shape.ie(2) {
                for j in shape.is_(1)..shape.ie(1) {
                    for i in shape.is_(0)..shape.ie(0) {
                        let rho = arr.as_slice()[shape.idx3(k, j, i)];
                        let e = arr.as_slice()[4 * n + shape.idx3(k, j, i)];
                        assert!(rho.is_finite() && rho > 0.0, "rho {rho}");
                        assert!(e.is_finite() && e > 0.0, "E {e}");
                    }
                }
            }
        }
    });
}
