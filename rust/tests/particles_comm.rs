//! Particle swarms: cross-block and cross-rank transport, periodic wrap,
//! count conservation, defrag under churn.

mod common;

use parthenon::comm::{tags, ReduceOp, World};
use parthenon::config::ParameterInput;
use parthenon::driver::HydroSim;
use parthenon::particles::{transport_until_done, Swarm, SwarmField};

fn deck() -> String {
    common::input_deck("uniform", [16, 16, 1], [8, 8, 1], "")
}

fn seed_swarm(sim: &mut HydroSim, per_block: usize) {
    for b in &mut sim.mesh.blocks {
        let mut sw = Swarm::new("tracers", &[SwarmField::Int("id".into())]);
        let idx = sw.add_particles(per_block);
        let gid = b.gid;
        for (n, &i) in idx.iter().enumerate() {
            let fx = 0.1 + 0.8 * (n as f32 / per_block.max(1) as f32);
            sw.real_field_mut("x").unwrap()[i] =
                (b.coords.xmin[0] + fx as f64 * (b.coords.xmax(0) - b.coords.xmin[0])) as f32;
            sw.real_field_mut("y").unwrap()[i] =
                (b.coords.xmin[1] + 0.5 * (b.coords.xmax(1) - b.coords.xmin[1])) as f32;
            sw.int_field_mut("id").unwrap()[i] = (gid * 1000 + n) as i64;
        }
        b.swarms.insert("tracers".into(), sw);
    }
}

fn total_particles(sim: &HydroSim) -> usize {
    sim.mesh
        .blocks
        .iter()
        .map(|b| b.swarms.get("tracers").map(|s| s.num_active()).unwrap_or(0))
        .sum()
}

#[test]
fn transport_conserves_particles_across_ranks() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    World::launch(4, |rank, world| {
        let pin = ParameterInput::from_str(&deck()).unwrap();
        let mut sim = HydroSim::new(pin, rank, world.clone()).unwrap();
        seed_swarm(&mut sim, 20);
        let comm = world.comm(rank, tags::COMM_PARTICLES_BASE);
        let coll = world.comm(rank, 0);
        let before = coll.allreduce(total_particles(&sim) as f64, ReduceOp::Sum);

        // push every particle +0.6 block widths in x (crosses boundaries),
        // repeat a few times around the periodic domain
        for _ in 0..6 {
            for b in &mut sim.mesh.blocks {
                if let Some(sw) = b.swarms.get_mut("tracers") {
                    for i in sw.active_indices() {
                        sw.real_field_mut("x").unwrap()[i] += 0.3;
                        sw.real_field_mut("y").unwrap()[i] += 0.17;
                    }
                }
            }
            transport_until_done(&mut sim.mesh, &comm, "tracers", 10).unwrap();
            // every particle must now be inside its block
            for b in &sim.mesh.blocks {
                let sw = b.swarms.get("tracers").unwrap();
                for i in sw.active_indices() {
                    let x = sw.real_field("x").unwrap()[i] as f64;
                    let y = sw.real_field("y").unwrap()[i] as f64;
                    assert!(
                        x >= b.coords.xmin[0] && x < b.coords.xmax(0),
                        "x {x} outside block [{}, {})",
                        b.coords.xmin[0],
                        b.coords.xmax(0)
                    );
                    assert!(y >= b.coords.xmin[1] && y < b.coords.xmax(1));
                }
            }
        }
        let after = coll.allreduce(total_particles(&sim) as f64, ReduceOp::Sum);
        assert_eq!(before, after, "particles lost or duplicated");
    });
}

#[test]
fn particle_ids_survive_migration_intact() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    World::launch(2, |rank, world| {
        let pin = ParameterInput::from_str(&deck()).unwrap();
        let mut sim = HydroSim::new(pin, rank, world.clone()).unwrap();
        seed_swarm(&mut sim, 8);
        let comm = world.comm(rank, tags::COMM_PARTICLES_BASE);
        let coll = world.comm(rank, 0);

        // checksum of ids before
        let sum_ids = |sim: &HydroSim| -> f64 {
            sim.mesh
                .blocks
                .iter()
                .flat_map(|b| {
                    let sw = b.swarms.get("tracers").unwrap();
                    sw.active_indices()
                        .into_iter()
                        .map(|i| sw.int_field("id").unwrap()[i] as f64)
                        .collect::<Vec<_>>()
                })
                .sum()
        };
        let before = coll.allreduce(sum_ids(&sim), ReduceOp::Sum);
        for b in &mut sim.mesh.blocks {
            let sw = b.swarms.get_mut("tracers").unwrap();
            for i in sw.active_indices() {
                sw.real_field_mut("x").unwrap()[i] -= 0.55;
            }
        }
        transport_until_done(&mut sim.mesh, &comm, "tracers", 10).unwrap();
        let after = coll.allreduce(sum_ids(&sim), ReduceOp::Sum);
        assert_eq!(before, after, "payload corrupted in flight");
    });
}

#[test]
fn outflow_boundary_absorbs_particles() {
    let world = World::new(1);
    let mut pin = ParameterInput::from_str(&deck()).unwrap();
    pin.set("parthenon/mesh", "ix1_bc", "outflow");
    pin.set("parthenon/mesh", "ox1_bc", "outflow");
    let mut sim = HydroSim::new(pin, 0, world.clone()).unwrap();
    seed_swarm(&mut sim, 10);
    let comm = world.comm(0, tags::COMM_PARTICLES_BASE);
    let before = total_particles(&sim);
    // push everything out through +x
    for _ in 0..8 {
        for b in &mut sim.mesh.blocks {
            if let Some(sw) = b.swarms.get_mut("tracers") {
                for i in sw.active_indices() {
                    sw.real_field_mut("x").unwrap()[i] += 0.4;
                }
            }
        }
        transport_until_done(&mut sim.mesh, &comm, "tracers", 10).unwrap();
    }
    let after = total_particles(&sim);
    assert!(after < before, "outflow must absorb ({before} -> {after})");
    assert_eq!(after, 0, "everything should eventually leave");
}
