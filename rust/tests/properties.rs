//! Cross-module property tests (xorshift-driven; see util::testutil).

mod common;

use std::collections::HashMap;

use parthenon::balance;
use parthenon::mesh::{AmrFlag, BlockTree};
use parthenon::util::rng::XorShift;
use parthenon::util::testutil::check;

#[test]
fn random_regrid_sequences_keep_invariants() {
    check("regrid invariants", 20, |rng: &mut XorShift| {
        let dim = 2 + rng.below(2); // 2 or 3
        let nrb = [1 + rng.below(3) as i64, 1 + rng.below(3) as i64, if dim == 3 { 1 + rng.below(2) as i64 } else { 1 }];
        let mut tree = BlockTree::uniform(nrb, dim, [true; 3]);
        let max_level = 3;
        for _ in 0..4 {
            let mut flags = HashMap::new();
            for l in tree.leaves() {
                let r = rng.next_f64();
                let flag = if r < 0.25 {
                    AmrFlag::Refine
                } else if r < 0.5 {
                    AmrFlag::Derefine
                } else {
                    AmrFlag::Same
                };
                flags.insert(*l, flag);
            }
            tree = tree.regrid(&flags, max_level);
            assert!(tree.is_properly_nested(), "nesting violated");
            tree.check_coverage().expect("coverage violated");
            assert!(tree.max_level() <= max_level);
            // neighbor symmetry: if A sees B same-level, B sees A
            for l in tree.leaves() {
                for nb in tree.find_neighbors(l) {
                    if let parthenon::mesh::NeighborKind::SameLevel(m) = nb.kind {
                        let back = tree.find_neighbors(&m);
                        let found = back.iter().any(|b| {
                            matches!(&b.kind,
                                parthenon::mesh::NeighborKind::SameLevel(x) if x == l)
                        });
                        assert!(found, "neighbor symmetry broken: {l:?} <-> {m:?}");
                    }
                }
            }
        }
    });
}

#[test]
fn balancer_partitions_are_contiguous_and_complete() {
    check("balance", 50, |rng: &mut XorShift| {
        let n = 1 + rng.below(300);
        let r = 1 + rng.below(12);
        let costs: Vec<f64> = (0..n).map(|_| 0.25 + 2.0 * rng.next_f64()).collect();
        let a = balance::assign_blocks(&costs, r);
        assert_eq!(a.len(), n);
        for w in a.windows(2) {
            assert!(w[1] == w[0] || w[1] == w[0] + 1 || w[1] > w[0]);
            assert!(w[1] >= w[0], "non-monotone assignment");
        }
        assert!(*a.iter().max().unwrap() < r);
        if n >= r {
            // every rank gets at least one block
            let counts = balance::assignment_counts(&a, r);
            assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        }
    });
}

#[test]
fn pack_planning_exactly_covers() {
    check("pack plan", 100, |rng: &mut XorShift| {
        let avail = vec![1, 2, 4, 8, 16];
        let n = rng.below(200);
        let desired = 1 + rng.below(32);
        let plan = parthenon::runtime::plan_packs(n, &avail, desired);
        assert_eq!(plan.iter().sum::<usize>(), n);
        for p in &plan {
            assert!(avail.contains(p));
            assert!(*p <= desired.max(1));
        }
    });
}

#[test]
fn message_storm_no_loss_no_reorder() {
    if !common::multi_rank_enabled() {
        return; // multi-rank coverage runs in its own CI step
    }
    use parthenon::comm::{Payload, World};
    check("simmpi storm", 5, |rng: &mut XorShift| {
        let nranks = 2 + rng.below(3);
        let nmsg = 50 + rng.below(100);
        let seed = rng.next_u64();
        World::launch(nranks, move |rank, world| {
            let comm = world.comm(rank, 7);
            let mut rng = XorShift::new(seed ^ rank as u64);
            // everyone sends nmsg messages to a ring neighbor with a
            // sequence number; receiver checks FIFO and completeness
            let dst = (rank + 1) % nranks;
            let src = (rank + nranks - 1) % nranks;
            for s in 0..nmsg {
                let jitter = rng.below(3);
                for _ in 0..jitter {
                    std::thread::yield_now();
                }
                comm.isend(dst, 42, Payload::F32(vec![s as f32]));
            }
            for s in 0..nmsg {
                let v = comm.recv(src, 42).unwrap().into_f32().unwrap();
                assert_eq!(v[0], s as f32, "reordered or lost");
            }
        });
    });
}

#[test]
fn exchange_is_deterministic_across_repeats() {
    // same initial data -> bitwise same ghosts, run twice
    use parthenon::driver::EvolutionDriver;
    let deck = common::input_deck("blast", [32, 32, 1], [8, 8, 1], "");
    let run = || {
        let mut sim = common::single_rank_sim(&deck, &[]);
        for _ in 0..3 {
            sim.step().unwrap();
        }
        common::cons_by_gid(&sim)
    };
    let a = run();
    let b = run();
    assert_eq!(common::max_state_diff(&a, &b), 0.0);
}
