//! Shared helpers for integration tests.
//!
//! Each integration-test crate includes this module; not every crate uses
//! every helper.
#![allow(dead_code)]

use parthenon::comm::World;
use parthenon::config::ParameterInput;
use parthenon::driver::{HydroSim, SimBuilder};
use parthenon::hydro::CONS;

/// Build an input deck string.
pub fn input_deck(problem: &str, nx: [usize; 3], bx: [usize; 3], extra: &str) -> String {
    let mut s = format!(
        "<parthenon/job>\nproblem = {problem}\nquiet = true\n\n\
         <parthenon/mesh>\nnx1 = {}\nnx2 = {}\nnx3 = {}\n\n\
         <parthenon/meshblock>\nnx1 = {}\nnx2 = {}\nnx3 = {}\n\n\
         <parthenon/time>\ntlim = 100.0\nnlim = -1\n\n\
         <hydro>\ngamma = 1.4\ncfl = 0.3\n",
        nx[0], nx[1], nx[2], bx[0], bx[1], bx[2]
    );
    s.push_str(extra);
    s
}

/// Build a single-rank sim from a deck.
pub fn single_rank_sim(deck: &str, overrides: &[&str]) -> HydroSim {
    let world = World::new(1);
    let mut pin = ParameterInput::from_str(deck).unwrap();
    for ov in overrides {
        pin.apply_override(ov).unwrap();
    }
    SimBuilder::new(pin).rank(0).world(world).build().unwrap()
}

/// Gather every local block's CONS data (gid -> INTERIOR data).
///
/// Interior only: the Device path leaves staging-ghost cells stale between
/// stages (they are overwritten by the next fused unpack), so ghost values
/// are not comparable across execution spaces.
pub fn cons_by_gid(sim: &HydroSim) -> Vec<(usize, Vec<f32>)> {
    let shape = sim.mesh.cfg.index_shape();
    let n = shape.ncells_total();
    sim.mesh
        .blocks
        .iter()
        .map(|b| {
            let arr = b.data.get(CONS).unwrap();
            let s = arr.as_slice();
            let mut out = Vec::with_capacity(5 * shape.ncells_interior());
            for v in 0..5 {
                for k in shape.is_(2)..shape.ie(2) {
                    for j in shape.is_(1)..shape.ie(1) {
                        for i in shape.is_(0)..shape.ie(0) {
                            out.push(s[v * n + shape.idx3(k, j, i)]);
                        }
                    }
                }
            }
            (b.gid, out)
        })
        .collect()
}

/// Max |a-b| over matching gids.
pub fn max_state_diff(a: &[(usize, Vec<f32>)], b: &[(usize, Vec<f32>)]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut m = 0.0f32;
    for ((ga, va), (gb, vb)) in a.iter().zip(b.iter()) {
        assert_eq!(ga, gb);
        for (x, y) in va.iter().zip(vb.iter()) {
            m = m.max((x - y).abs());
        }
    }
    m
}

/// Whether the Device execution space can run. Always true with the native
/// artifact interpreter (real AOT artifacts are used when present).
pub fn artifacts_available() -> bool {
    parthenon::runtime::device_available()
}

/// Rank-thread budget for multi-rank tests: `PARTHENON_TEST_RANKS`
/// (default 2, so a plain local `cargo test` keeps full coverage). CI
/// splits the suite into a single-rank step (`PARTHENON_TEST_RANKS=1`,
/// multi-rank tests skip) and a multi-rank step (`PARTHENON_TEST_RANKS=2`)
/// so rank-dependent failures are attributable to the step that owns them.
pub fn test_ranks() -> usize {
    std::env::var("PARTHENON_TEST_RANKS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(2)
}

/// True when multi-rank tests should run (see [`test_ranks`]). Tests that
/// spawn more than one rank-thread call this first and return early in
/// the single-rank CI step.
///
/// IMPORTANT: when adding this guard to a test in a binary that doesn't
/// already use it, also add that binary to the `--test ...` list of the
/// "Test (multi-rank)" step in `.github/workflows/ci.yml` — otherwise the
/// guarded test is skipped in the single-rank step and never runs in CI.
pub fn multi_rank_enabled() -> bool {
    test_ranks() >= 2
}
